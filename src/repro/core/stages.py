"""Staged compression pipeline over a first-class ``Chunk`` IR (DESIGN.md §9).

``codec.compress`` is a thin composition of the stages below:

    parse -> dedup -> structure -> encode -> pack

Each stage reads and fills declared fields of a ``Chunk`` — the unit of
work for both the batch path (one chunk = the whole corpus) and a
``StreamingCompressor`` session (``repro.core.stream``: chunks cut by
line/byte budget, sharing one growing ``TemplateStore``). The structure
stage has two modes:

- **batch** (default): ISE over the whole chunk, or match-only against a
  frozen ``cfg.template_store`` — archive layout identical to the
  pre-refactor monolithic codec.
- **session** (``store=`` + ``grow=True``): match against the shared
  store first, run ISE only on the unmatched remainder, append the new
  templates to the store and serialize only the *delta*. EventIDs in
  ``meta["stream"]["used"]`` are the store's global ids, stable across
  every chunk of the session (and across appends).
"""

from __future__ import annotations

import bz2
import json
import lzma
import zlib
from dataclasses import dataclass, field as dfield

import numpy as np

from . import integrity
from .encode import (
    ColumnCodec,
    ParamDict,
    encode_varints,
    esc,
    factorize,
    join_column,
    pack_container,
)
from .ise import ISEConfig, ISEResult, iterative_structure_extraction
from .match import extract_spans, match_first
from .templates import TemplateStore
from .textops import first_occurrence_unique
from .timing import StageTimer
from .tokenizer import STAR_ID, LogFormat, TokenGrid, Vocab, tokenize_batch

FILE_MAGIC = b"LZJF"
WILDCARD_MARK = "\x02"

KERNELS: dict[str, tuple[int, object, object]] = {
    "gzip": (0, lambda b: zlib.compress(b, 6), zlib.decompress),
    "bzip2": (1, lambda b: bz2.compress(b, 9), bz2.decompress),
    "lzma": (2, lambda b: lzma.compress(b, preset=6), lzma.decompress),
    "none": (3, lambda b: b, lambda b: b),
}
KERNEL_BY_ID = {v[0]: k for k, v in KERNELS.items()}


@dataclass
class LogzipConfig:
    level: int = 3                  # 1 | 2 | 3 (paper's levels)
    kernel: str = "gzip"
    format: str | None = None       # loghub format string, None = content-only
    max_tokens: int = 128
    ise: ISEConfig = dfield(default_factory=ISEConfig)
    # paper §III-E: a pre-extracted TemplateStore skips ISE — new logs are
    # matched against the stored templates (stable EventIDs across archives)
    template_store: object = None
    # dedup fast path: tokenize / span-extract each *distinct* content
    # string once and fan results back out by inverse index. Byte-identical
    # archives either way (property-tested); False only exists as the
    # reference path for that test and for ablation benchmarks.
    dedup: bool = True
    # session mode: a template discovered by remainder-ISE enters the
    # shared store only if it matched at least this many lines in its
    # chunk; below-threshold lines go verbatim. Guards the store against
    # over-specific one-off templates (literal params baked in), which
    # bloat the delta stream and slow every later chunk's match pass.
    stream_min_support: int = 2
    # typed parameter-column codecs (DESIGN.md §12): classify each
    # header/star column (timestamp / monotone / numeric / mini-dict /
    # ip-hex) and store it under the typed layout; columns that do not
    # classify fall back to the v1 text layout. Bumps the archive meta
    # version to 2; False reproduces the v1 bytes exactly (the committed
    # v1 golden fixtures are built this way).
    typed_columns: bool = True
    # CRC32C per-frame trailers (DESIGN.md §13): every frame the writers
    # emit — the LZJF kernel payload, LZJS header / chunk / delta /
    # footer frames — is followed by a 4-byte checksum, and each LZJS
    # chunk is sealed by a commit record so a torn-off footer can be
    # rebuilt by scanning. Bumps the container version to 3; False
    # reproduces the v1/v2 bytes exactly (the committed v1/v2 golden
    # fixtures are built this way).
    integrity: bool = True
    # per-chunk query screens (DESIGN.md §14): v3 LZJS sessions append a
    # CRC-sealed optional SCRN frame after each chunk's commit — Bloom
    # filters over cold ParamDict references and high-cardinality header
    # fields — so point queries open O(1) chunks. Pre-screen readers
    # skip the frames (they sit inside the indexed record range); False
    # reproduces the screen-free v3 bytes exactly (golden fixtures).
    screens: bool = True
    screen_fpp: float = 0.02


class StreamSession:
    """Mutable cross-chunk state of a streaming compression session.

    Both members are append-only with get-or-assign interning, so the
    global ids they hand out (EventIDs, ParaIDs) are stable for the life
    of the session — chunks serialize only the *delta* each added.
    Memory grows with the number of DISTINCT templates / parameter
    values, not with the corpus.
    """

    def __init__(self, store: TemplateStore | None = None,
                 paradict: ParamDict | None = None):
        self.store = store if store is not None else TemplateStore()
        self.paradict = paradict if paradict is not None else ParamDict()


def serialize_template(tokens: list[str | None]) -> str:
    return "\x00".join(WILDCARD_MARK if t is None else esc(t) for t in tokens)


# ----------------------------------------------------------------- Chunk IR

@dataclass
class Chunk:
    """Unit of work flowing through the staged pipeline.

    Stages fill fields progressively; ``objects`` / ``meta`` accumulate
    the archive representation that ``pack_stage`` frames into ``blob``.
    """

    lines: list[str]
    # -- parse_stage
    fmt: LogFormat | None = None
    columns: dict = dfield(default_factory=dict)
    ok_idx: list[int] = dfield(default_factory=list)
    bad_idx: list[int] = dfield(default_factory=list)
    contents: list[str] = dfield(default_factory=list)
    # -- dedup_stage
    inverse: np.ndarray | None = None        # line -> distinct-content index
    uniq: list[str] | None = None
    grid: TokenGrid | None = None            # batched tokens/delims/offsets
    vocab: Vocab | None = None
    ids_u: np.ndarray | None = None
    lens_u: np.ndarray | None = None
    ids: np.ndarray | None = None
    lens: np.ndarray | None = None
    levels: np.ndarray | None = None
    comps: np.ndarray | None = None
    # -- structure_stage
    templates: list = dfield(default_factory=list)  # token-id arrays, chunk vocab
    assign: np.ndarray | None = None         # per ok-line template id (-1 verbatim)
    match_rate: float = 1.0
    session: bool = False                     # store-global EventID mode
    tpl_base: int = 0                         # store size before this chunk
    n_delta: int = 0                          # templates this chunk added
    tpl_strings: list | None = None           # store string tuples (global ids)
    pd_base: int = 0                          # session paradict size before chunk
    delta_templates: list | None = None       # serialized new templates (session)
    delta_params: list | None = None          # new ParamDict values (session)
    # -- encode/pack
    objects: dict[str, bytes] = dfield(default_factory=dict)
    meta: dict = dfield(default_factory=dict)
    coltypes: dict = dfield(default_factory=dict)  # column -> type summary
    blob: bytes | None = None


# ------------------------------------------------------------------ stages

def parse_stage(ch: Chunk, cfg: LogzipConfig, tm: StageTimer,
                session: StreamSession | None = None) -> None:
    """L1: header/content split, verbatim channel for parse failures,
    header-field columns."""
    ch.meta.update({"v": 2 if cfg.typed_columns else 1, "level": cfg.level,
                    "n": len(ch.lines), "format": cfg.format})
    with tm("parse"):
        ch.fmt = LogFormat(cfg.format) if cfg.format else None
        if ch.fmt is not None:
            ch.columns, ch.ok_idx, ch.bad_idx = ch.fmt.parse(ch.lines)
            ch.contents = ch.columns[ch.fmt.content_field]
            ch.meta["fields"] = ch.fmt.fields
        else:
            ch.columns, ch.ok_idx, ch.bad_idx = {}, list(range(len(ch.lines))), []
            ch.contents = list(ch.lines)
    ch.objects["raw.idx"] = encode_varints(np.diff(np.array([-1] + ch.bad_idx)))
    ch.objects["raw.txt"] = join_column([ch.lines[i] for i in ch.bad_idx])
    with tm("columns"):
        for f in (ch.fmt.fields if ch.fmt else []):
            if f == ch.fmt.content_field:
                continue
            ch.objects.update(ColumnCodec(
                f"h.{f}", typed=cfg.typed_columns, type_sink=ch.coltypes,
                use_kernel=cfg.ise.use_kernel,
                wide_ints_text=session is not None).encode(ch.columns[f]))


def dedup_stage(ch: Chunk, cfg: LogzipConfig, tm: StageTimer) -> None:
    """Factorize distinct contents, tokenize / vocab-encode once each
    (DESIGN.md §1.1 — archive bytes identical with ``cfg.dedup`` off)."""
    n = len(ch.contents)
    with tm("dedup"):
        if cfg.dedup:
            ch.inverse, ch.uniq = factorize(ch.contents)
        else:
            ch.inverse, ch.uniq = np.arange(n, dtype=np.int64), list(ch.contents)
    with tm("tokenize"):
        ch.vocab = Vocab()
        ch.grid = tokenize_batch(ch.uniq, ch.vocab, cfg.max_tokens, tight=True)
    with tm("encode"):
        ch.ids_u, ch.lens_u = ch.grid.ids, ch.grid.lens
        ch.ids = ch.ids_u[ch.inverse]
        ch.lens = ch.lens_u[ch.inverse]
        ch.levels = factorize(ch.columns["Level"])[0] if "Level" in ch.columns else None
        ch.comps = factorize(ch.columns["Component"])[0] if "Component" in ch.columns else None


def structure_stage(ch: Chunk, cfg: LogzipConfig, tm: StageTimer,
                    session: StreamSession | None = None) -> None:
    """Assign every line a template id.

    Batch mode: full ISE (or match-only against a frozen
    ``cfg.template_store``). Session mode: match against the shared
    store first, ISE only the unmatched remainder, grow the store with
    the newly-discovered templates.
    """
    if session is not None:
        _structure_session(ch, cfg, tm, session.store)
        return
    if cfg.template_store is not None:
        tpl_ids = cfg.template_store.to_id_arrays(ch.vocab)
        with tm("ise.match"):
            a = match_first(ch.ids, ch.lens, tpl_ids, use_kernel=cfg.ise.use_kernel)
        res = ISEResult(tpl_ids, a, [float((a >= 0).mean())], [])
        ch.meta["template_store"] = True
    else:
        res = iterative_structure_extraction(ch.ids, ch.lens, ch.levels, ch.comps,
                                             len(ch.vocab), cfg.ise, stage_times=tm.sink)
    ch.templates = res.templates
    ch.match_rate = res.match_rate
    ch.assign = res.assign.astype(np.int64)
    ch.assign[ch.lens > cfg.max_tokens] = -1  # over-budget lines go verbatim


def _structure_session(ch: Chunk, cfg: LogzipConfig, tm: StageTimer, store) -> None:
    ch.session = True
    ch.tpl_base = len(store)
    n = ch.ids.shape[0]
    assign = np.full((n,), -1, np.int64)
    if len(store):
        with tm("ise.match"):
            a = match_first(ch.ids, ch.lens, store.to_id_arrays(ch.vocab),
                            use_kernel=cfg.ise.use_kernel)
        assign = a.astype(np.int64)
    rem = np.nonzero(assign < 0)[0]
    if rem.size:
        res = iterative_structure_extraction(
            ch.ids[rem], ch.lens[rem],
            ch.levels[rem] if ch.levels is not None else None,
            ch.comps[rem] if ch.comps is not None else None,
            len(ch.vocab), cfg.ise, stage_times=tm.sink)
        if res.templates:
            # promote only supported templates (cfg.stream_min_support);
            # lines of dropped one-off templates go verbatim instead of
            # polluting every later chunk's store
            support = np.bincount(res.assign[res.assign >= 0],
                                  minlength=len(res.templates))
            local_to_global = np.full(len(res.templates), -1, np.int64)
            for j, tpl in enumerate(res.templates):
                if support[j] >= cfg.stream_min_support:
                    local_to_global[j] = store.add(tuple(
                        None if int(t) == STAR_ID else ch.vocab.token(int(t))
                        for t in tpl))
            hit = res.assign >= 0
            assign[rem] = np.where(hit, local_to_global[np.maximum(res.assign, 0)], -1)
    ch.match_rate = float((assign >= 0).mean()) if n else 1.0
    assign[ch.lens > cfg.max_tokens] = -1
    ch.assign = assign
    ch.n_delta = len(store) - ch.tpl_base
    ch.tpl_strings = list(store.templates)
    # id arrays in THIS chunk's vocab. For store-matched templates these
    # are the arrays the DP matched with; for templates just discovered
    # here every literal is in the chunk vocab, so the round trip through
    # strings is exact.
    ch.templates = store.to_id_arrays(ch.vocab)


def encode_stage(ch: Chunk, cfg: LogzipConfig, tm: StageTimer,
                 session: StreamSession | None = None) -> None:
    """L2/L3: verbatim channel for unmatched lines, template + EventID
    objects, per-template star-value columns and gap patterns.

    Session chunks share the session's ``ParamDict`` and serialize only
    its delta (``pd.delta``) — ParaIDs are global across the stream."""
    if cfg.level == 1:
        ch.objects["content.txt"] = join_column(ch.contents)
        return
    assign = ch.assign

    # verbatim channel for unmatched content (indices within the ok-lines)
    un_pos = np.nonzero(assign < 0)[0]
    ch.objects["cun.idx"] = encode_varints(np.diff(np.concatenate([[-1], un_pos])))
    ch.objects["cun.txt"] = join_column([ch.contents[i] for i in un_pos])

    # compact remap of used templates — UNLESS global EventIDs are in
    # play (frozen store or streaming session): downstream consumers key
    # on the store's ids, so those are preserved
    if ch.session:
        used = sorted(set(int(a) for a in assign if a >= 0))
        # the template delta rides in the container record FRAME (see
        # repro.core.stream), not in the kernel-compressed blob — random
        # access reads deltas without decoding chunk payloads
        delta = ch.tpl_strings[ch.tpl_base:ch.tpl_base + ch.n_delta]
        ch.delta_templates = [serialize_template(list(t)) for t in delta]
        ch.meta["stream"] = {"base": ch.tpl_base, "n_delta": ch.n_delta, "used": used}
    elif cfg.template_store is not None:
        used = list(range(len(ch.templates)))
    else:
        used = sorted(set(int(a) for a in assign if a >= 0))
    ch.meta["n_templates"] = len(used)
    ch.meta["match_rate"] = ch.match_rate

    if not ch.session:
        tser: list[str] = []
        for g in used:
            if cfg.template_store is not None:
                # store literals may be absent from THIS corpus's vocab —
                # serialize from the store's own strings
                toks = list(cfg.template_store.templates[g])
            else:
                toks = [None if int(t) == STAR_ID else ch.vocab.token(int(t))
                        for t in ch.templates[g]]
            tser.append(serialize_template(toks))
        ch.objects["templates"] = join_column(tser)

    matched = np.nonzero(assign >= 0)[0]
    remap_arr = np.full(len(ch.templates), -1, np.int64)
    remap_arr[np.asarray(used, np.int64)] = np.arange(len(used))
    ch.objects["events"] = encode_varints(remap_arr[assign[matched]])

    vocab_arr = np.array([ch.vocab.token(i) for i in range(len(ch.vocab))], dtype=object)
    paradict = None
    if cfg.level >= 3:
        paradict = session.paradict if (ch.session and session is not None) else ParamDict()
        ch.pd_base = len(paradict.values)
    for k, g in enumerate(used):
        tpl = ch.templates[g]
        line_idx = np.nonzero(assign == g)[0]
        with tm("spans"):
            star_cols, pat_list, pat_ids = _template_params(
                tpl, line_idx, ch.inverse, ch.grid, vocab_arr)
        with tm("columns"):
            for s, col in enumerate(star_cols):
                ch.objects.update(ColumnCodec(
                    f"t{k}.v{s}", paradict, typed=cfg.typed_columns,
                    type_sink=ch.coltypes, use_kernel=cfg.ise.use_kernel,
                    wide_ints_text=ch.session).encode(col))
            ch.objects[f"t{k}.gap.pat"] = join_column(pat_list)
            ch.objects[f"t{k}.gap.pid"] = encode_varints(pat_ids)

    if paradict is not None:
        if ch.session and session is not None:
            ch.delta_params = list(paradict.values[ch.pd_base:])
            ch.meta["stream"]["pd_base"] = ch.pd_base
            ch.meta["stream"]["pd_delta"] = len(paradict.values) - ch.pd_base
        else:
            ch.objects["paradict"] = paradict.encode()
    if cfg.typed_columns and ch.coltypes:
        # per-column type table (inspect / downstream stats; the full
        # summaries additionally feed the LZJS chunk manifest)
        ch.meta["coltypes"] = {name: info["t"] for name, info in ch.coltypes.items()}


def pack_stage(ch: Chunk, cfg: LogzipConfig, tm: StageTimer) -> bytes:
    ch.objects["meta"] = json.dumps(ch.meta).encode("utf-8")
    with tm("pack"):
        container = pack_container(ch.objects)
    kid, comp, _ = KERNELS[cfg.kernel]
    with tm("kernel"):
        blob = comp(container)
    if cfg.integrity:
        # v3: bit 7 of the level byte flags a CRC32C trailer over
        # everything before it (levels are 1-3, so the bit is free)
        body = FILE_MAGIC + bytes([kid, cfg.level | 0x80]) + blob
        ch.blob = body + integrity.trailer(body)
    else:
        ch.blob = FILE_MAGIC + bytes([kid, cfg.level]) + blob
    return ch.blob


def run_stages(
    lines: list[str],
    cfg: LogzipConfig | None = None,
    *,
    stage_times: dict | None = None,
    session: StreamSession | None = None,
) -> Chunk:
    """parse -> dedup -> structure -> encode over one chunk — everything
    *except* the entropy kernel. ``pack_stage`` is split out so callers
    can overlap it with the next chunk's compute (the double-buffered
    handoff in ``repro.core.stream`` / ``repro.core.parallel``: gzip of
    chunk k runs on a worker thread — zlib/bz2/lzma release the GIL —
    while chunk k+1 is tokenized and matched here)."""
    cfg = cfg or LogzipConfig()
    if cfg.level not in (1, 2, 3):
        raise ValueError("level must be 1, 2 or 3")
    if session is not None and cfg.template_store is not None:
        raise ValueError("session mode grows its own store; cfg.template_store must be None")
    tm = StageTimer(stage_times)
    ch = Chunk(lines=lines)
    parse_stage(ch, cfg, tm, session=session)
    if cfg.level >= 2:
        dedup_stage(ch, cfg, tm)
        structure_stage(ch, cfg, tm, session=session)
    encode_stage(ch, cfg, tm, session=session)
    return ch


def run_pipeline(
    lines: list[str],
    cfg: LogzipConfig | None = None,
    *,
    stage_times: dict | None = None,
    session: StreamSession | None = None,
) -> Chunk:
    """parse -> dedup -> structure -> encode -> pack over one chunk."""
    cfg = cfg or LogzipConfig()
    ch = run_stages(lines, cfg, stage_times=stage_times, session=session)
    pack_stage(ch, cfg, StageTimer(stage_times))
    return ch


def _template_params(tpl, line_idx, inverse, grid: TokenGrid, vocab_arr):
    """Star-value columns + gap-pattern dictionary for one template.

    All heavy work runs once per distinct content: spans come from the
    fused anchor matcher on the unique rows, star substrings from one
    vectorized vocab lookup (single-token spans, the common case) or an
    O(1) byte slice of the original content (multi-token spans), and gap
    patterns are computed once per distinct (star widths, interned delim
    row) class — identical to walking every line, because the gap
    sequence is a pure function of that key for a fixed template.
    """
    u_lines = inverse[line_idx]
    uu_inv, ufirst = first_occurrence_unique(u_lines)
    uu_arr = u_lines[ufirst]  # uniques in first-line-occurrence order
    spans_u = extract_spans(grid.ids[uu_arr], grid.lens[uu_arr], tpl)
    n_uu, n_stars = spans_u.shape[:2]
    widths = spans_u[:, :, 1] - spans_u[:, :, 0]

    ustar = np.empty((n_uu, n_stars), dtype=object)
    for si in range(n_stars):
        single = widths[:, si] == 1
        if single.any():
            rows = np.nonzero(single)[0]
            ustar[rows, si] = vocab_arr[grid.ids[uu_arr[rows], spans_u[rows, si, 0]]]
        for r in np.nonzero(~single)[0]:
            u = int(uu_arr[r])
            ustar[r, si] = grid.substring(u, int(spans_u[r, si, 0]), int(spans_u[r, si, 1]))

    # gap (unit-delimiter) pattern per (widths, delim-row) class: rows in
    # one class share every delimiter run and every star width, so the
    # walk below runs once per class, not once per unique line
    tpl_is_star = [int(t) == STAR_ID for t in tpl]
    dl = grid.delim_ids[uu_arr]
    gkey = np.ascontiguousarray(np.concatenate([widths.astype(np.int32), dl], axis=1))
    rows_v = gkey.view(np.dtype((np.void, gkey.shape[1] * gkey.itemsize))).ravel()
    ginv, gfirst = first_occurrence_unique(rows_v)
    dtab = [esc(d) for d in grid.delim_table]
    class_pat: list[str] = []
    for r in gfirst.tolist():
        drow = dl[r]
        gaps = [dtab[drow[0]]]
        si = 0
        pos = 0
        for is_star in tpl_is_star:
            if is_star:
                pos = int(spans_u[r, si, 1])
                si += 1
            else:
                pos += 1
            gaps.append(dtab[drow[pos]])
        class_pat.append("\x00".join(gaps))

    # intern patterns over classes (class order == first-occurrence order
    # over unique lines, so pattern ids match the per-line scan)
    pat_map: dict[str, int] = {}
    pat_list: list[str] = []
    cpid = np.empty(len(class_pat), np.int64)
    for j, p in enumerate(class_pat):
        pid = pat_map.get(p)
        if pid is None:
            pid = len(pat_list)
            pat_map[p] = pid
            pat_list.append(p)
        cpid[j] = pid
    upid = cpid[ginv]

    star_cols = [ustar[uu_inv, si].tolist() for si in range(n_stars)]
    return star_cols, pat_list, upid[uu_inv]
