"""Baseline compressors for the paper's Table II comparison.

- ``kernel_baseline``: raw gzip / bzip2 / lzma over the file (the paper's
  main baselines).
- ``logarchive_like``: simplified re-implementation of LogArchive
  (Christensen & Li, SIGMOD'13): lines are adaptively routed to buckets by
  similarity to each bucket's recent window; buckets are compressed
  separately; a per-line bucket index restores order. Approximation — the
  original is not available offline (noted in DESIGN.md §6.4).
- ``cowic_like``: simplified Cowic (Lin et al., CCGrid'15): column-wise
  split by whitespace position, one object per column, compressed
  per-column (Cowic optimizes query latency, not CR — expect CR ~ gzip,
  as in the paper).

All are lossless and share the same kernel implementations as logzip, so
comparisons isolate the *representation*, not the entropy coder.
"""

from __future__ import annotations

from collections import deque

from .codec import KERNELS
from .encode import join_column, pack_container, split_column, unpack_container, encode_varints, decode_varints


def kernel_baseline(lines: list[str], kernel: str = "gzip") -> bytes:
    return KERNELS[kernel][1]("\n".join(lines).encode("utf-8"))


def kernel_baseline_decompress(blob: bytes, kernel: str = "gzip") -> list[str]:
    return KERNELS[kernel][2](blob).decode("utf-8").split("\n")


# ------------------------------------------------------------- LogArchive

def _sim(a: set, b: set) -> float:
    if not a or not b:
        return 0.0
    return len(a & b) / max(len(a), len(b))


def logarchive_like(lines: list[str], kernel: str = "gzip", n_buckets: int = 16, window: int = 8) -> bytes:
    buckets: list[list[str]] = [[] for _ in range(n_buckets)]
    windows: list[deque] = [deque(maxlen=window) for _ in range(n_buckets)]
    route: list[int] = []
    for line in lines:
        toks = set(line.split())
        best, best_s = 0, -1.0
        for b in range(n_buckets):
            s = max((_sim(toks, w) for w in windows[b]), default=0.0)
            if s > best_s:
                best, best_s = b, s
        if best_s <= 0.0:  # start filling empty buckets round-robin
            empties = [b for b in range(n_buckets) if not buckets[b]]
            if empties:
                best = empties[0]
        route.append(best)
        buckets[best].append(line)
        windows[best].append(toks)
    objs = {"route": encode_varints(route)}
    for b in range(n_buckets):
        objs[f"b{b}"] = join_column(buckets[b])
    return KERNELS[kernel][1](pack_container(objs))


def logarchive_like_decompress(blob: bytes, kernel: str = "gzip") -> list[str]:
    objs = unpack_container(KERNELS[kernel][2](blob))
    route = decode_varints(objs["route"])
    cols = {}
    cursors = {}
    out = []
    for b in route:
        if b not in cols:
            cols[b] = split_column(objs[f"b{b}"])
            cursors[b] = 0
        out.append(cols[b][cursors[b]])
        cursors[b] += 1
    return out


# ------------------------------------------------------------------ Cowic

def cowic_like(lines: list[str], kernel: str = "gzip", max_cols: int = 16) -> bytes:
    cols: list[list[str]] = [[] for _ in range(max_cols)]
    for line in lines:
        parts = line.split(" ", max_cols - 1)
        for c in range(max_cols):
            cols[c].append(parts[c] if c < len(parts) else "\x00")
    objs = {f"c{c}": join_column(col) for c, col in enumerate(cols)}
    objs["n"] = encode_varints([len(lines)])
    return KERNELS[kernel][1](pack_container(objs))


def cowic_like_decompress(blob: bytes, kernel: str = "gzip", max_cols: int = 16) -> list[str]:
    objs = unpack_container(KERNELS[kernel][2](blob))
    n = decode_varints(objs["n"])[0]
    cols = [split_column(objs[f"c{c}"]) for c in range(max_cols)]
    out = []
    for r in range(n):
        parts = [cols[c][r] for c in range(max_cols) if cols[c][r] != "\x00"]
        out.append(" ".join(parts))
    return out
