"""Per-chunk screens for O(1)-chunk point queries (DESIGN.md §14).

A *screen* is a small, CRC-sealed optional frame appended to an LZJS
chunk record AFTER its commit record. It carries split-block Bloom
filters (SBBF, the Parquet construction) that bound which chunks can
realize a value, consulted by the query engine before any gunzip:

- a **param bloom** over the chunk's *cold* ParamDict references: a
  session ParaID that appears in few chunks is the signature of a
  high-cardinality point value (a block id, a request id). Hot ids —
  everything referenced by more than ``COLD_REF_CHUNKS + 1`` chunks —
  are never screened (the footer's ``screens.cold`` list tells the
  reader which ids are bloom-decidable at all), so the filters stay
  tiny while point queries touch O(1) chunks.
- **field blooms** over the distinct values of high-cardinality header
  fields (the ones whose manifest summary carries no ``v`` value list).

Soundness contract (property-tested screened ≡ unscreened): a screen
may only claim "this chunk CANNOT contain the value". The writer inserts
every cold old-reference it counts; readers treat any id outside the
cold list — including ids the writer never counted, e.g. short or
non-alphanumeric values — as hot, i.e. unprunable. Frames ride inside
the record's indexed byte range, so pre-screen v3 readers (and the
footer-driven random-access paths) skip them for free, and ``OPT1``
frames of *unknown* kind are skipped by construction — forward compat
for future optional frames.
"""

from __future__ import annotations

import numpy as np

from . import integrity
from .encode import write_varint

OPT_MAGIC = b"OPT1"
SCREEN_KIND = b"SCRN"
SCREEN_VERSION = 1

# minimum alphanumeric-run length the param screen indexes; shorter
# needles fall back to the ParamDict watermark screen alone. Matches the
# scale of WIDE_INT_TEXT identifiers the session dict is built to dedup.
RUN_MIN_LEN = 8
# a ParaID referenced by at most this many OTHER chunks (beyond its
# introducing chunk) is cold: bloom-decidable. Ids seen in more chunks
# are hot — screening them buys little pruning and costs bloom bits.
COLD_REF_CHUNKS = 1
# counters saturate here: every decision (insert eligibility at
# <= COLD_REF_CHUNKS, coldness at <= COLD_REF_CHUNKS + 1) is already
# settled once a count reaches this bound, so persisted counters lose
# nothing by capping — the footer meta stays small on hot ids.
COUNT_CAP = COLD_REF_CHUNKS + 2
DEFAULT_FPP = 0.02
# per-chunk byte budget across all of a chunk's blooms (<1% of archive
# size on the benchmark corpora, CR-gated); the param bloom has priority
SCREEN_CHUNK_BUDGET = 1536
FIELD_BLOOM_MAX_KEYS = 512

_BLOCK_BYTES = 32  # 8 x uint32 words per SBBF block

# Parquet SBBF salt constants — one odd multiplier per word lane
_SALTS = np.array([
    0x47b6137b, 0x44974d91, 0x8824ad5b, 0xa2b7289d,
    0x705495c7, 0x2df1424b, 0x9efc4947, 0x5c6bfb31,
], dtype=np.uint64)

_M64 = (1 << 64) - 1


def _splitmix64(x: int) -> int:
    x = (x + 0x9E3779B97F4A7C15) & _M64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _M64
    return x ^ (x >> 31)


def _hash_key(key: int | str) -> int:
    """Deterministic 64-bit hash; dependency-free so writer and readers
    across processes/platforms agree bit-for-bit."""
    if isinstance(key, int):
        return _splitmix64(key & _M64)
    h = 0xCBF29CE484222325  # FNV-1a 64 over utf-8, then finalize
    for b in key.encode("utf-8"):
        h = ((h ^ b) * 0x100000001B3) & _M64
    return _splitmix64(h)


class SBBF:
    """Split-block Bloom filter: 32-byte blocks, 8 bits per key (one per
    word lane), block chosen by the hash's high 32 bits. No false
    negatives ever; FPP ≈ (1 - e^(-8/c))^8 at c bits/key."""

    def __init__(self, nblocks: int):
        self.nblocks = max(1, int(nblocks))
        self.words = np.zeros(self.nblocks * 8, dtype=np.uint32)

    @classmethod
    def sized(cls, n_keys: int, fpp: float = DEFAULT_FPP,
              max_bytes: int | None = None) -> "SBBF":
        c = 8.0 / -np.log1p(-float(fpp) ** (1.0 / 8.0))  # bits per key
        nblocks = int(np.ceil(c * max(1, n_keys) / (_BLOCK_BYTES * 8)))
        if max_bytes is not None:
            nblocks = min(nblocks, max(1, max_bytes // _BLOCK_BYTES))
        return cls(nblocks)

    def _mask(self, key: int | str) -> tuple[int, np.ndarray]:
        h = _hash_key(key)
        block = (h >> 32) % self.nblocks
        x = np.uint64(h & 0xFFFFFFFF)
        bits = ((x * _SALTS) >> np.uint64(27)) & np.uint64(31)
        return int(block), (np.uint32(1) << bits.astype(np.uint32))

    def add(self, key: int | str) -> None:
        block, mask = self._mask(key)
        self.words[block * 8:block * 8 + 8] |= mask

    def contains(self, key: int | str) -> bool:
        block, mask = self._mask(key)
        w = self.words[block * 8:block * 8 + 8]
        return bool(np.all(w & mask == mask))

    @property
    def nbytes(self) -> int:
        return self.nblocks * _BLOCK_BYTES

    def to_bytes(self) -> bytes:
        return self.words.astype("<u4").tobytes()

    @classmethod
    def from_bytes(cls, data: bytes) -> "SBBF":
        if not data or len(data) % _BLOCK_BYTES:
            raise ValueError(f"SBBF payload not block-aligned: {len(data)} bytes")
        f = cls(len(data) // _BLOCK_BYTES)
        f.words = np.frombuffer(data, dtype="<u4").astype(np.uint32)
        return f


def bloom_fpp(n_keys: int, nbytes: int) -> float:
    """Predicted false-positive rate of an SBBF holding ``n_keys`` in
    ``nbytes`` (surfaced in ``grep --stats`` next to the observed rate)."""
    if not n_keys or not nbytes:
        return 0.0
    c = nbytes * 8.0 / n_keys
    return float((1.0 - np.exp(-8.0 / c)) ** 8)


# -------------------------------------------------------------- frame codec

def _uvarint(payload: bytes, pos: int) -> tuple[int, int]:
    v = shift = 0
    while True:
        if pos >= len(payload):
            raise ValueError("truncated varint in screen payload")
        b = payload[pos]
        pos += 1
        v |= (b & 0x7F) << shift
        if not b & 0x80:
            return v, pos
        shift += 7


def build_screen_payload(param_bloom: SBBF | None, param_keys: int,
                         field_blooms: dict[str, tuple[SBBF, int]]) -> bytes:
    out = bytearray([SCREEN_VERSION])
    write_varint(out, param_keys)
    write_varint(out, param_bloom.nblocks if param_bloom is not None else 0)
    if param_bloom is not None:
        out += param_bloom.to_bytes()
    write_varint(out, len(field_blooms))
    for name in sorted(field_blooms):
        bloom, n_keys = field_blooms[name]
        nb = name.encode("utf-8")
        write_varint(out, len(nb))
        out += nb
        write_varint(out, n_keys)
        write_varint(out, bloom.nblocks)
        out += bloom.to_bytes()
    return bytes(out)


def build_opt_frame(kind: bytes, payload: bytes) -> bytes:
    """``OPT1 | kind(4) | varint(len) | payload | crc32c`` — the CRC
    seals the whole frame, magic and kind included."""
    if len(kind) != 4:
        raise ValueError("optional-frame kind must be 4 bytes")
    body = bytearray(OPT_MAGIC)
    body += kind
    write_varint(body, len(payload))
    body += payload
    return bytes(body) + integrity.trailer(bytes(body))


class ChunkScreen:
    """Parsed read side of one chunk's ``SCRN`` frame."""

    def __init__(self, param: SBBF | None, param_keys: int,
                 fields: dict[str, tuple[SBBF, int]]):
        self.param = param
        self.param_keys = param_keys
        self.fields = fields

    def may_contain_param(self, pid: int) -> bool:
        """MAY the chunk reference cold ParaID ``pid``? No-bloom chunks
        answer yes (sound)."""
        return True if self.param is None else self.param.contains(int(pid))

    def field_may_contain(self, name: str, value: str) -> bool | None:
        """Tri-state: None when the field has no bloom (undecidable)."""
        ent = self.fields.get(name)
        if ent is None:
            return None
        return ent[0].contains(value)


def parse_screen_payload(payload: bytes) -> ChunkScreen:
    if not payload or payload[0] != SCREEN_VERSION:
        raise ValueError(f"unknown screen version {payload[:1]!r}")
    pos = 1
    param_keys, pos = _uvarint(payload, pos)
    nblocks, pos = _uvarint(payload, pos)
    param = None
    if nblocks:
        end = pos + nblocks * _BLOCK_BYTES
        param = SBBF.from_bytes(payload[pos:end])
        pos = end
    n_fields, pos = _uvarint(payload, pos)
    fields: dict[str, tuple[SBBF, int]] = {}
    for _ in range(n_fields):
        nlen, pos = _uvarint(payload, pos)
        name = payload[pos:pos + nlen].decode("utf-8")
        pos += nlen
        fkeys, pos = _uvarint(payload, pos)
        fblocks, pos = _uvarint(payload, pos)
        end = pos + fblocks * _BLOCK_BYTES
        fields[name] = (SBBF.from_bytes(payload[pos:end]), fkeys)
        pos = end
    return ChunkScreen(param, param_keys, fields)


def skip_opt_frames(data: bytes, pos: int) -> int:
    """Advance ``pos`` past any well-formed optional frames (salvage gap
    walks: commit-derived record lengths exclude trailing screens, so the
    walker must hop over them to reach the next ``CHNK``). Screens are
    expendable — a malformed frame simply stops the skip."""
    while data[pos:pos + 4] == OPT_MAGIC:
        try:
            plen, p = _uvarint(data, pos + 8)
        except ValueError:
            break
        end = p + plen + integrity.CRC_LEN
        if end > len(data):
            break
        pos = end
    return pos


# ------------------------------------------------------------------ builder

class ScreenBuilder:
    """Session-lifetime screen state on the write side.

    Tracks, per ParaID, how many chunks have referenced it (its
    introducing chunk included). ``chunk_screen`` is called once per
    chunk — BEFORE the counters are advanced — and inserts into that
    chunk's bloom every *old* reference (``pid < pd_base``) whose prior
    chunk-count is still ≤ ``COLD_REF_CHUNKS``; at close,
    ``cold_params()`` reports which ids stayed bloom-decidable. Ids the
    builder never counted (short values, values that are not a single
    alphanumeric run) are absent from the cold list, so readers treat
    them as hot — never bloom-tested — keeping the screen sound.
    """

    def __init__(self, fpp: float = DEFAULT_FPP,
                 budget: int = SCREEN_CHUNK_BUDGET,
                 counts: dict[int, int] | None = None):
        self.fpp = float(fpp)
        self.budget = int(budget)
        self._counts: dict[int, int] = dict(counts) if counts else {}

    @classmethod
    def restore(cls, meta: dict, *, fpp: float = DEFAULT_FPP,
                budget: int = SCREEN_CHUNK_BUDGET) -> "ScreenBuilder | None":
        """Rebuild a builder from a footer ``screens`` entry so an
        append session keeps emitting sound frames (the counters are the
        cross-chunk state the frames' soundness depends on). Returns
        None for archives written before the counters were persisted —
        those appends must keep dropping screens, as they always did."""
        if not isinstance(meta, dict) or "c1" not in meta or "hot" not in meta:
            return None
        counts = {int(p): COUNT_CAP for p in meta["hot"]}
        for p in meta.get("cold", []):
            counts[int(p)] = COLD_REF_CHUNKS + 1
        for p in meta["c1"]:
            counts[int(p)] = 1
        return cls(float(meta.get("fpp", fpp)), budget, counts=counts)

    def chunk_refs(self, texts, to_id_get, pd_base: int, pd_end: int
                   ) -> tuple[set[int], set[int]]:
        """Scan the chunk's line texts for ParamDict references.

        Returns ``(old_refs, all_refs)``: distinct referenced ids split
        by whether the id predates this chunk. Only ids below ``pd_end``
        count — the pack worker runs concurrently with the next chunk's
        encode growing the shared dict, and ids introduced later cannot
        be realized by THIS chunk's parameter values.
        """
        from .query import _ALNUM_RUN_RE  # single source of run syntax

        refs: set[int] = set()
        for t in texts:
            for m in _ALNUM_RUN_RE.finditer(t):
                if m.end() - m.start() < RUN_MIN_LEN:
                    continue
                pid = to_id_get(m.group())
                if pid is not None and pid < pd_end:
                    refs.add(pid)
        return {p for p in refs if p < pd_base}, refs

    def chunk_screen(self, old_refs: set[int], all_refs: set[int],
                     field_cols: dict[str, list[str]] | None = None,
                     field_has_vals: dict[str, bool] | None = None) -> bytes | None:
        """Build one chunk's ``SCRN`` frame (or None when empty), then
        advance the per-id chunk counters."""
        cold_old = [p for p in old_refs if self._counts.get(p, 0) <= COLD_REF_CHUNKS]
        for p in all_refs:
            self._counts[p] = min(self._counts.get(p, 0) + 1, COUNT_CAP)

        spent = 0
        param = None
        if cold_old:
            param = SBBF.sized(len(cold_old), self.fpp, max_bytes=self.budget)
            for p in cold_old:
                param.add(p)
            spent = param.nbytes

        fields: dict[str, tuple[SBBF, int]] = {}
        for name, col in (field_cols or {}).items():
            if field_has_vals and field_has_vals.get(name):
                continue  # manifest value list already decides equality
            distinct = set(col)
            if not distinct or len(distinct) > FIELD_BLOOM_MAX_KEYS:
                continue
            room = self.budget - spent
            if room < _BLOCK_BYTES:
                break
            bloom = SBBF.sized(len(distinct), self.fpp, max_bytes=room)
            for v in distinct:
                bloom.add(v)
            fields[name] = (bloom, len(distinct))
            spent += bloom.nbytes

        if param is None and not fields:
            return None
        payload = build_screen_payload(param, len(cold_old), fields)
        return build_opt_frame(SCREEN_KIND, payload)

    def cold_params(self) -> list[int]:
        """Ids whose total chunk-count stayed within the cold bound —
        the ONLY ids readers may test against the param blooms."""
        bound = COLD_REF_CHUNKS + 1
        return sorted(p for p, c in self._counts.items() if c <= bound)

    def meta(self) -> dict:
        """Footer ``screens`` entry: reader-side protocol constants plus
        the saturated reference counters (``c1`` = cold ids still at one
        chunk, ``hot`` = ids past the cold bound), which ``restore``
        re-seeds an append session from. Readers ignore the extra keys."""
        return {"r": COLD_REF_CHUNKS, "fpp": self.fpp,
                "minrun": RUN_MIN_LEN, "cold": self.cold_params(),
                "c1": sorted(p for p, c in self._counts.items() if c == 1),
                "hot": sorted(p for p, c in self._counts.items()
                              if c > COLD_REF_CHUNKS + 1)}
