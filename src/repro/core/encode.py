"""Object encoders for the logzip 3-level representation (paper §IV-B).

Everything here is lossless by construction:

- ``varint`` streams for id columns (EventIDs, pattern ids, ParaIDs).
  (The paper renders ParaIDs as base-64 *text*; we use LEB128 binary —
  same idea, strictly denser before the kernel. Recorded in DESIGN.md.)
- ``esc``/``unesc`` make arbitrary strings newline-safe so columns can be
  newline-joined.
- ``ColumnCodec``: the paper's sub-field splitting. Each value is split on
  runs of non-alphanumeric characters; the delimiter skeleton becomes a
  *pattern* (interned in a dictionary, one varint id per line) and the
  alphanumeric runs become per-slot columns. With ``dictionary=True``
  (Level 3) slot values are additionally interned in a shared
  ``ParamDict`` and stored as varint ParaIDs.
"""

from __future__ import annotations

import re

# ---------------------------------------------------------------- varint

def write_varint(out: bytearray, v: int) -> None:
    while True:
        b = v & 0x7F
        v >>= 7
        if v:
            out.append(b | 0x80)
        else:
            out.append(b)
            return


def encode_varints(values) -> bytes:
    out = bytearray()
    for v in values:
        write_varint(out, int(v))
    return bytes(out)


def decode_varints(data: bytes) -> list[int]:
    out: list[int] = []
    cur = 0
    shift = 0
    for b in data:
        cur |= (b & 0x7F) << shift
        if b & 0x80:
            shift += 7
        else:
            out.append(cur)
            cur = 0
            shift = 0
    return out


# ---------------------------------------------------------------- escaping

def esc(s: str) -> str:
    return (
        s.replace("\\", "\\\\")
        .replace("\n", "\\n")
        .replace("\r", "\\r")
        .replace("\x00", "\\0")
        .replace("\x02", "\\2")
    )


def unesc(s: str) -> str:
    out = []
    i = 0
    n = len(s)
    while i < n:
        c = s[i]
        if c == "\\" and i + 1 < n:
            nxt = s[i + 1]
            out.append({"\\": "\\", "n": "\n", "r": "\r", "0": "\x00", "2": "\x02"}.get(nxt, "\\" + nxt))
            i += 2
        else:
            out.append(c)
            i += 1
    return "".join(out)


def join_column(values: list[str]) -> bytes:
    """varint count prefix + newline-joined escaped values (unambiguous
    for [] vs [""])."""
    head = bytearray()
    write_varint(head, len(values))
    return bytes(head) + "\n".join(esc(v) for v in values).encode("utf-8")


def split_column(data: bytes) -> list[str]:
    n = 0
    shift = 0
    pos = 0
    while True:
        b = data[pos]
        pos += 1
        n |= (b & 0x7F) << shift
        if not (b & 0x80):
            break
        shift += 7
    if n == 0:
        return []
    vals = data[pos:].decode("utf-8").split("\n")
    assert len(vals) == n, (len(vals), n)
    return [unesc(v) for v in vals]


# ---------------------------------------------------------------- ParamDict

class ParamDict:
    """Global value->ParaID dictionary shared by all groups (paper L3)."""

    def __init__(self):
        self._to_id: dict[str, int] = {}
        self.values: list[str] = []

    def id(self, value: str) -> int:
        i = self._to_id.get(value)
        if i is None:
            i = len(self.values)
            self._to_id[value] = i
            self.values.append(value)
        return i

    def encode(self) -> bytes:
        return join_column(self.values)

    @staticmethod
    def decode(data: bytes) -> list[str]:
        return split_column(data)


# ---------------------------------------------------------------- columns

_SLOT_RE = re.compile(r"[0-9A-Za-z]+")


def split_subfields(value: str) -> tuple[str, list[str]]:
    """Split on non-alphanumeric runs. -> (pattern with \\x00 slots, parts)."""
    parts = _SLOT_RE.findall(value)
    pattern = _SLOT_RE.sub("\x00", value)
    return pattern, parts


def merge_subfields(pattern: str, parts: list[str]) -> str:
    segs = pattern.split("\x00")
    out = [segs[0]]
    for seg, part in zip(segs[1:], parts):
        out.append(part)
        out.append(seg)
    return "".join(out)


class ColumnCodec:
    """Sub-field columnarization of one string column (paper L1/L2/L3).

    encode(values) -> {name.pat: pattern dict, name.pid: varint pattern ids,
                       name.s<k>: slot-k column (text or varint ParaIDs)}
    Slot columns are grouped *per pattern* so that values sharing a
    skeleton land in the same object (the paper's coherence argument).
    """

    def __init__(self, name: str, paradict: ParamDict | None = None):
        self.name = name
        self.paradict = paradict

    def encode(self, values: list[str]) -> dict[str, bytes]:
        patterns: dict[str, int] = {}
        pat_list: list[str] = []
        pat_ids: list[int] = []
        slots: dict[tuple[int, int], list] = {}  # (pattern id, slot) -> parts
        for v in values:
            # escape first so the \x00 slot marker can never collide with
            # value bytes; decode merges then un-escapes.
            pattern, parts = split_subfields(esc(v))
            pid = patterns.get(pattern)
            if pid is None:
                pid = len(pat_list)
                patterns[pattern] = pid
                pat_list.append(pattern)
            pat_ids.append(pid)
            for k, part in enumerate(parts):
                slots.setdefault((pid, k), []).append(part)
        objs: dict[str, bytes] = {
            f"{self.name}.pat": join_column(pat_list),
            f"{self.name}.pid": encode_varints(pat_ids),
        }
        for (pid, k), parts in sorted(slots.items()):
            key = f"{self.name}.p{pid}s{k}"
            if self.paradict is not None:
                objs[key] = encode_varints(self.paradict.id(p) for p in parts)
            else:
                objs[key] = join_column(parts)
        return objs

    def decode(self, objs: dict[str, bytes], n: int, paravalues: list[str] | None = None) -> list[str]:
        pat_list = split_column(objs[f"{self.name}.pat"])
        pat_ids = decode_varints(objs[f"{self.name}.pid"])
        assert len(pat_ids) == n, (self.name, len(pat_ids), n)
        cursors: dict[tuple[int, int], int] = {}
        slot_cols: dict[tuple[int, int], list[str]] = {}
        out: list[str] = []
        for pid in pat_ids:
            pattern = pat_list[pid]
            n_slots = pattern.count("\x00")
            parts = []
            for k in range(n_slots):
                col = slot_cols.get((pid, k))
                if col is None:
                    raw = objs[f"{self.name}.p{pid}s{k}"]
                    if paravalues is not None:
                        col = [paravalues[i] for i in decode_varints(raw)]
                    else:
                        col = split_column(raw)
                    slot_cols[(pid, k)] = col
                c = cursors.get((pid, k), 0)
                parts.append(col[c])
                cursors[(pid, k)] = c + 1
            out.append(unesc(merge_subfields(pattern, parts)))
        return out


# ------------------------------------------------------------- container

MAGIC = b"LZJ1"


def pack_container(objects: dict[str, bytes]) -> bytes:
    out = bytearray(MAGIC)
    write_varint(out, len(objects))
    for name, data in objects.items():
        nb = name.encode("utf-8")
        write_varint(out, len(nb))
        out += nb
        write_varint(out, len(data))
        out += data
    return bytes(out)


def unpack_container(data: bytes) -> dict[str, bytes]:
    assert data[:4] == MAGIC, "bad container magic"
    pos = 4

    def rd_varint() -> int:
        nonlocal pos
        cur = 0
        shift = 0
        while True:
            b = data[pos]
            pos += 1
            cur |= (b & 0x7F) << shift
            if not (b & 0x80):
                return cur
            shift += 7

    n = rd_varint()
    objects: dict[str, bytes] = {}
    for _ in range(n):
        ln = rd_varint()
        name = data[pos : pos + ln].decode("utf-8")
        pos += ln
        dl = rd_varint()
        objects[name] = data[pos : pos + dl]
        pos += dl
    return objects
