"""Object encoders for the logzip 3-level representation (paper §IV-B).

Everything here is lossless by construction:

- ``varint`` streams for id columns (EventIDs, pattern ids, ParaIDs).
  (The paper renders ParaIDs as base-64 *text*; we use LEB128 binary —
  same idea, strictly denser before the kernel. Recorded in DESIGN.md §3.)
- ``esc``/``unesc`` make arbitrary strings newline-safe so columns can be
  newline-joined.
- ``ColumnCodec``: the paper's sub-field splitting. Each value is split on
  runs of non-alphanumeric characters; the delimiter skeleton becomes a
  *pattern* (interned in a dictionary, one varint id per line) and the
  alphanumeric runs become per-slot columns. With ``dictionary=True``
  (Level 3) slot values are additionally interned in a shared
  ``ParamDict`` and stored as varint ParaIDs.
"""

from __future__ import annotations

import re
import string

import numpy as np

from .textops import SegmentHasher, class_mask, first_occurrence_unique, intern_segments, runs_of

_ALNUM_LUT = class_mask(string.digits + string.ascii_letters)

# ---------------------------------------------------------------- varint

def write_varint(out: bytearray, v: int) -> None:
    while True:
        b = v & 0x7F
        v >>= 7
        if v:
            out.append(b | 0x80)
        else:
            out.append(b)
            return


def encode_varints(values) -> bytes:
    """LEB128-encode a sequence of non-negative ints, vectorized.

    Identical byte output to a per-value ``write_varint`` loop; the whole
    stream is assembled with numpy (single-byte fast path for id columns
    that fit in 7 bits, which is most of them)."""
    arr = values if isinstance(values, np.ndarray) else np.asarray(list(values))
    if arr.size == 0:
        return b""
    if arr.dtype == object or arr.dtype.kind not in "iu":
        # arbitrary-precision values (or non-int input): scalar fallback
        out = bytearray()
        for v in arr.ravel():
            write_varint(out, int(v))
        return bytes(out)
    v = arr.astype(np.uint64).ravel()
    if int(v.max()) < 0x80:
        return v.astype(np.uint8).tobytes()
    nbytes = np.ones(v.shape, np.int64)
    x = v >> np.uint64(7)
    while x.any():
        nbytes += x > 0
        x >>= np.uint64(7)
    ends = np.cumsum(nbytes)
    starts = ends - nbytes
    out = np.zeros(int(ends[-1]), np.uint8)
    for b in range(int(nbytes.max())):
        sel = nbytes > b
        byte = ((v[sel] >> np.uint64(7 * b)) & np.uint64(0x7F)).astype(np.uint8)
        cont = (nbytes[sel] > b + 1).astype(np.uint8) << 7
        out[starts[sel] + b] = byte | cont
    return out.tobytes()


def decode_varints(data: bytes) -> list[int]:
    out: list[int] = []
    cur = 0
    shift = 0
    for b in data:
        cur |= (b & 0x7F) << shift
        if b & 0x80:
            shift += 7
        else:
            out.append(cur)
            cur = 0
            shift = 0
    return out


# ---------------------------------------------------------------- escaping

_ESC_RE = re.compile(r"[\\\n\r\x00\x02]")


def esc(s: str) -> str:
    # almost every value needs no escaping — one C-level scan beats five
    # replace passes (byte-identical output either way)
    if _ESC_RE.search(s) is None:
        return s
    return (
        s.replace("\\", "\\\\")
        .replace("\n", "\\n")
        .replace("\r", "\\r")
        .replace("\x00", "\\0")
        .replace("\x02", "\\2")
    )


def unesc(s: str) -> str:
    out = []
    i = 0
    n = len(s)
    while i < n:
        c = s[i]
        if c == "\\" and i + 1 < n:
            nxt = s[i + 1]
            out.append({"\\": "\\", "n": "\n", "r": "\r", "0": "\x00", "2": "\x02"}.get(nxt, "\\" + nxt))
            i += 2
        else:
            out.append(c)
            i += 1
    return "".join(out)


def join_column(values: list[str], already_safe: bool = False) -> bytes:
    """varint count prefix + newline-joined escaped values (unambiguous
    for [] vs [""]).

    ``already_safe=True`` skips the per-value ``esc`` pass for values the
    caller guarantees contain no escapable bytes (e.g. alphanumeric
    sub-field parts) — byte-identical output, since ``esc`` is the
    identity on such strings."""
    head = bytearray()
    write_varint(head, len(values))
    joined = "\n".join(values) if already_safe else "\n".join(esc(v) for v in values)
    return bytes(head) + joined.encode("utf-8")


def split_column(data: bytes) -> list[str]:
    n = 0
    shift = 0
    pos = 0
    while True:
        b = data[pos]
        pos += 1
        n |= (b & 0x7F) << shift
        if not (b & 0x80):
            break
        shift += 7
    if n == 0:
        return []
    vals = data[pos:].decode("utf-8").split("\n")
    assert len(vals) == n, (len(vals), n)
    return [unesc(v) for v in vals]


# ---------------------------------------------------------------- ParamDict

class ParamDict:
    """Global value->ParaID dictionary shared by all groups (paper L3).

    Append-only, so a streaming session can share ONE dict across chunks:
    seed it with the accumulated values, then ``encode_delta(base)``
    serializes only the values this chunk added — ParaIDs are global and
    stable for the life of the session (mirrors ``TemplateStore.add``).
    """

    def __init__(self, seed: list[str] | None = None):
        self.values: list[str] = list(seed) if seed else []
        self._to_id: dict[str, int] = {v: i for i, v in enumerate(self.values)}

    def id(self, value: str) -> int:
        i = self._to_id.get(value)
        if i is None:
            i = len(self.values)
            self._to_id[value] = i
            self.values.append(value)
        return i

    def encode(self) -> bytes:
        return join_column(self.values)

    def encode_delta(self, base: int) -> bytes:
        return join_column(self.values[base:])

    @staticmethod
    def decode(data: bytes) -> list[str]:
        return split_column(data)


# ---------------------------------------------------------------- columns

def factorize(values) -> tuple[np.ndarray, list]:
    """(inverse indices, distinct values in first-occurrence order).

    The first-occurrence order is load-bearing: every dedup fast path in
    the codec relies on it to reproduce the non-dedup byte stream
    (pattern ids, ParaIDs and vocab ids are all assigned at first
    occurrence). One implementation, shared — do not fork it."""
    seen: dict = {}
    inv = np.empty(len(values), np.int64)
    uniq: list = []
    for i, v in enumerate(values):
        j = seen.get(v)
        if j is None:
            j = len(uniq)
            seen[v] = j
            uniq.append(v)
        inv[i] = j
    return inv, uniq


_SLOT_RE = re.compile(r"[0-9A-Za-z]+")


def split_subfields(value: str) -> tuple[str, list[str]]:
    """Split on non-alphanumeric runs. -> (pattern with \\x00 slots, parts)."""
    parts = _SLOT_RE.findall(value)
    pattern = _SLOT_RE.sub("\x00", value)
    return pattern, parts


def split_subfields_batch(values: list[str]) -> tuple[list[str], np.ndarray, list[str], np.ndarray]:
    """``split_subfields`` over a batch in a few numpy passes.

    -> (patterns, part ids (flat, row-major), part table, row_ptr): the
    parts of ``values[j]`` are ``table[pid]`` for ``pid`` in
    ``part_ids[row_ptr[j]:row_ptr[j+1]]``, with the table in
    first-occurrence order. Values must be pre-escaped (``esc``), which
    guarantees they are newline-free so the batch can be newline-joined;
    anything that defeats utf-8 encoding falls back to the scalar loop.
    """
    n = len(values)
    row_ptr = np.zeros(n + 1, np.int64)
    if n == 0:
        return [], np.zeros(0, np.int64), [], row_ptr
    try:
        data = "\n".join(values).encode("utf-8", "surrogateescape")
    except UnicodeEncodeError:
        pats: list[str] = []
        flat: list[int] = []
        table: list[str] = []
        seen: dict[str, int] = {}
        for j, v in enumerate(values):
            pat, parts = split_subfields(v)
            pats.append(pat)
            for s in parts:
                i = seen.get(s)
                if i is None:
                    i = len(table)
                    seen[s] = i
                    table.append(s)
                flat.append(i)
            row_ptr[j + 1] = len(flat)
        return pats, np.asarray(flat, np.int64), table, row_ptr

    buf = np.frombuffer(data, np.uint8)
    alnum = _ALNUM_LUT[buf]
    starts, ends = runs_of(alnum)
    part_ids, table = intern_segments(data, SegmentHasher(buf), starts, ends)

    # patterns: drop alnum-run bytes, write \x00 at each run start
    keep = ~alnum
    marked = buf.copy()
    marked[starts] = 0
    keep[starts] = True
    pats = marked[keep].tobytes().decode("utf-8", "surrogateescape").split("\n")

    nl = np.flatnonzero(buf == 0x0A)
    line_starts = np.concatenate([[0], nl + 1])
    line_of = np.searchsorted(line_starts, starts, side="right") - 1
    np.cumsum(np.bincount(line_of, minlength=n), out=row_ptr[1:])
    return pats, part_ids, table, row_ptr


def merge_subfields(pattern: str, parts: list[str]) -> str:
    segs = pattern.split("\x00")
    out = [segs[0]]
    for seg, part in zip(segs[1:], parts):
        out.append(part)
        out.append(seg)
    return "".join(out)


class ColumnCodec:
    """Sub-field columnarization of one string column (paper L1/L2/L3).

    encode(values) -> {name.pat: pattern dict, name.pid: varint pattern ids,
                       name.s<k>: slot-k column (text or varint ParaIDs)}
    Slot columns are grouped *per pattern* so that values sharing a
    skeleton land in the same object (the paper's coherence argument).

    With ``typed=True`` (v2 archives, DESIGN.md §12) the column is first
    run through ``repro.core.coltypes``: columns that classify as an
    integer family / mini-dict / IP-hex type are stored under their typed
    layout (``name.ct`` descriptor + payloads) instead — level-3 typed
    values no longer enter the shared ``ParamDict``. TEXT fallbacks (and
    every v1 archive) use the layout below unchanged; decode dispatches
    on the presence of ``name.ct``. ``type_sink`` receives the per-column
    type summary (feeds ``meta["coltypes"]`` and the LZJS manifests);
    ``use_kernel`` routes the integer transforms through the Pallas
    delta/zigzag kernel (byte-identical output).
    """

    def __init__(self, name: str, paradict: ParamDict | None = None, *,
                 typed: bool = False, type_sink: dict | None = None,
                 use_kernel: bool = False, wide_ints_text: bool = False):
        self.name = name
        self.paradict = paradict
        self.typed = typed
        self.type_sink = type_sink
        self.use_kernel = use_kernel
        self.wide_ints_text = wide_ints_text

    def encode(self, values: list[str]) -> dict[str, bytes]:
        """Byte-identical to the per-value reference loop, but the
        escape / sub-field split work runs once per *distinct* value in
        a few numpy passes (``split_subfields_batch``), with parts
        hash-interned so ParaID lookups hit an int-keyed cache. All
        interning stays in first-occurrence order, so pattern ids and
        ParaID assignment order are unchanged."""
        n = len(values)
        inv, uvals = factorize(values)
        if self.typed:
            from .coltypes import encode_typed

            typed = encode_typed(self.name, values, uvals,
                                 use_kernel=self.use_kernel,
                                 wide_ints_text=self.wide_ints_text)
            if typed is not None:
                objs, summary = typed
                if self.type_sink is not None:
                    self.type_sink[self.name] = summary
                return objs
            if self.type_sink is not None:
                self.type_sink[self.name] = {"t": "text", "n": n}
        # escape first so the \x00 slot marker can never collide with
        # value bytes; decode merges then un-escapes.
        pats, part_ids, part_table, prow = split_subfields_batch([esc(v) for v in uvals])
        patterns: dict[str, int] = {}
        pat_list: list[str] = []
        upid = np.empty(len(uvals), np.int64)
        for j, pattern in enumerate(pats):
            pid = patterns.get(pattern)
            if pid is None:
                pid = len(pat_list)
                patterns[pattern] = pid
                pat_list.append(pattern)
            upid[j] = pid
        pat_ids = upid[inv] if n else np.zeros(0, np.int64)
        objs: dict[str, bytes] = {
            f"{self.name}.pat": join_column(pat_list),
            f"{self.name}.pid": encode_varints(pat_ids),
        }
        # one stable argsort groups value occurrences by pattern while
        # preserving value order within each group (single pass, no
        # per-pattern rescan of the whole column)
        order = np.argsort(pat_ids, kind="stable")
        counts = np.bincount(pat_ids, minlength=len(pat_list)).astype(np.int64)
        pd_cache: dict[int, int] = {}  # part id -> ParaID (same first-use order)
        group_start = 0
        for pid in range(len(pat_list)):
            c = int(counts[pid])
            us = inv[order[group_start:group_start + c]]  # uniques, value order
            group_start += c
            u0 = int(us[0])
            n_slots = int(prow[u0 + 1] - prow[u0])
            if n_slots == 0:
                continue
            # group the unique-value ids within this pattern group so
            # per-slot work (ParaID interning / joining) is per distinct
            # value; first-occurrence order keeps ParaIDs identical.
            g_inv, gfirst = first_occurrence_unique(us)
            g_uniq = us[gfirst]
            for k in range(n_slots):
                key = f"{self.name}.p{pid}s{k}"
                pids_k = part_ids[prow[g_uniq] + k]
                if self.paradict is not None:
                    uids = np.empty(len(g_uniq), np.int64)
                    pd_id = self.paradict.id
                    for idx, p in enumerate(pids_k.tolist()):
                        v = pd_cache.get(p)
                        if v is None:
                            v = pd_id(part_table[p])
                            pd_cache[p] = v
                        uids[idx] = v
                    objs[key] = encode_varints(uids[g_inv])
                else:
                    # parts are alphanumeric runs -> esc is the identity
                    col_u = [part_table[p] for p in pids_k.tolist()]
                    objs[key] = join_column([col_u[g] for g in g_inv], already_safe=True)
        return objs

    def decode(self, objs: dict[str, bytes], n: int, paravalues: list[str] | None = None) -> list[str]:
        if f"{self.name}.ct" in objs:  # typed column (v2, DESIGN.md §12)
            from .coltypes import decode_typed

            return decode_typed(self.name, objs, n)
        uniq, inv = self.decode_distinct(objs, n, paravalues)
        return [uniq[j] for j in inv]

    def decode_distinct(
        self, objs: dict[str, bytes], n: int, paravalues: list[str] | None = None,
    ) -> tuple[list[str], np.ndarray]:
        """Column-selective decode without full row materialization:
        -> (distinct values in first-occurrence order, inverse indices).

        The expensive per-row work (sub-field merge + unescape, and for
        Level 3 the ParaID -> string lookups) runs once per *distinct*
        (pattern, parts) row — log parameter columns are dominated by
        repeats, and the compressed-domain query engine evaluates
        predicates on the distinct values only, broadcasting the verdict
        through ``inverse``."""
        if f"{self.name}.ct" in objs:  # typed column (v2, DESIGN.md §12)
            from .coltypes import decode_typed

            inv, uniq = factorize(decode_typed(self.name, objs, n))
            return uniq, inv
        pat_list = split_column(objs[f"{self.name}.pat"])
        pat_ids = decode_varints(objs[f"{self.name}.pid"])
        assert len(pat_ids) == n, (self.name, len(pat_ids), n)
        cursors: dict[int, int] = {}
        slot_cols: dict[int, list[list]] = {}  # pid -> per-slot raw columns
        seen: dict[tuple, int] = {}
        uniq: list[str] = []
        inv = np.empty(n, np.int64)
        for r, pid in enumerate(pat_ids):
            cols = slot_cols.get(pid)
            if cols is None:
                n_slots = pat_list[pid].count("\x00")
                cols = []
                for k in range(n_slots):
                    raw = objs[f"{self.name}.p{pid}s{k}"]
                    # keep Level-3 columns as raw ParaIDs: the dedup key
                    # hashes ints and values are only looked up once per
                    # distinct row below
                    cols.append(decode_varints(raw) if paravalues is not None
                                else split_column(raw))
                slot_cols[pid] = cols
            c = cursors.get(pid, 0)
            cursors[pid] = c + 1
            key = (pid, *(col[c] for col in cols))
            j = seen.get(key)
            if j is None:
                parts = ([paravalues[i] for i in key[1:]] if paravalues is not None
                         else list(key[1:]))
                j = len(uniq)
                seen[key] = j
                uniq.append(unesc(merge_subfields(pat_list[pid], parts)))
            inv[r] = j
        return uniq, inv


# ------------------------------------------------------------- container

MAGIC = b"LZJ1"


def pack_container(objects: dict[str, bytes]) -> bytes:
    out = bytearray(MAGIC)
    write_varint(out, len(objects))
    for name, data in objects.items():
        nb = name.encode("utf-8")
        write_varint(out, len(nb))
        out += nb
        write_varint(out, len(data))
        out += data
    return bytes(out)


def unpack_container(data: bytes) -> dict[str, bytes]:
    assert data[:4] == MAGIC, "bad container magic"
    pos = 4

    def rd_varint() -> int:
        nonlocal pos
        cur = 0
        shift = 0
        while True:
            b = data[pos]
            pos += 1
            cur |= (b & 0x7F) << shift
            if not (b & 0x80):
                return cur
            shift += 7

    n = rd_varint()
    objects: dict[str, bytes] = {}
    for _ in range(n):
        ln = rd_varint()
        name = data[pos : pos + ln].decode("utf-8")
        pos += ln
        dl = rd_varint()
        objects[name] = data[pos : pos + dl]
        pos += dl
    return objects
