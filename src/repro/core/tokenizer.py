"""Log line parsing + content tokenization for logzip (paper §II, §IV-B L1).

A ``LogFormat`` turns a loghub-style format string, e.g.::

    "<Date> <Time> <Level> <Component>: <Content>"

into a compiled regex with named groups (same convention as logparser /
the original logzip). ``parse`` splits every raw line into header-field
columns plus the free-text message content; lines that do not match the
format are routed to a verbatim side-channel so compression stays lossless.

``tokenize`` splits message content into (tokens, delimiters) where the
delimiter strings are preserved exactly: ``reassemble(tokens, delims)``
is byte-identical to the input. Matching/clustering operate on tokens
only; delimiters ride along in a pattern-dictionary column.

``Vocab`` maps token strings to int32 ids for the accelerator path.
id 0 is PAD, id 1 is the wildcard ``*`` (never produced by tokenize:
literal "*" tokens are escaped on entry).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

from .textops import SegmentHasher, class_mask, intern_segments, runs_of

PAD_ID = 0
STAR_ID = 1
_N_RESERVED = 2

# Token delimiters used by the paper's implementation: whitespace plus a
# small set of punctuation. A "token" is a maximal run of non-delimiter
# characters; delimiter runs are preserved verbatim.
DEFAULT_DELIMITERS = " \t,;:="
_TOKEN_RE_CACHE: dict[str, re.Pattern] = {}


def _token_re(delimiters: str) -> re.Pattern:
    pat = _TOKEN_RE_CACHE.get(delimiters)
    if pat is None:
        cls = re.escape(delimiters)
        pat = re.compile(rf"[^{cls}]+")
        _TOKEN_RE_CACHE[delimiters] = pat
    return pat


def tokenize(content: str, delimiters: str = DEFAULT_DELIMITERS) -> tuple[list[str], list[str]]:
    """Split ``content`` into (tokens, delims).

    ``len(delims) == len(tokens) + 1``; delims[0] / delims[-1] are the
    (possibly empty) leading / trailing delimiter runs.
    """
    # Two C-level regex passes instead of a Python loop over runs:
    # findall gives the maximal token runs, split gives the delimiter runs
    # around them (including the possibly-empty leading/trailing runs).
    pat = _token_re(delimiters)
    return pat.findall(content), pat.split(content)


def reassemble(tokens: list[str], delims: list[str]) -> str:
    out = [delims[0]]
    for t, d in zip(tokens, delims[1:]):
        out.append(t)
        out.append(d)
    return "".join(out)


# ------------------------------------------------------------- TokenGrid

@dataclass
class TokenGrid:
    """Batched tokenization result over the distinct contents of a chunk
    (DESIGN.md §10): the device-layout twin of per-line ``tokenize`` +
    ``Vocab.encode_batch``.

    ``ids``/``lens`` are exactly what ``encode_batch`` returns. Token and
    delimiter *strings* are interned: ``vocab`` holds tokens (same ids,
    same first-occurrence order as the scalar path), ``delim_table``
    holds the distinct delimiter runs with ``delim_ids[u, j]`` the run
    before token ``j`` of line ``u`` (column ``lens[u]`` is the trailing
    run). Raw byte offsets are kept so multi-token parameter substrings
    are O(1) slices of the original content instead of token/delim
    joins.
    """

    ids: np.ndarray          # (U, W) int32 vocab ids, PAD-padded
    lens: np.ndarray         # (U,) int32 true token counts (may exceed W)
    delim_ids: np.ndarray    # (U, W+1) int32 into delim_table
    delim_table: list[str]
    data: bytes              # utf-8 of the concatenated contents
    tok_starts: np.ndarray   # flat byte offsets of in-budget tokens
    tok_ends: np.ndarray
    row_ptr: np.ndarray      # (U+1,) flat index of each line's first token

    def substring(self, u: int, s: int, e: int) -> str:
        """Content substring spanning tokens [s, e) of line ``u`` with the
        interior delimiters — byte-identical to joining tokens/delims."""
        base = self.row_ptr[u]
        return self.data[self.tok_starts[base + s]:self.tok_ends[base + e - 1]].decode(
            "utf-8", "surrogateescape")

    def line_delims(self, u: int) -> list[str]:
        """The ``delims`` list of line ``u`` (len = lens[u] + 1), for
        rows within the width budget."""
        t = int(self.lens[u])
        return [self.delim_table[i] for i in self.delim_ids[u, :t + 1]]


def _cumsum0(a: np.ndarray) -> np.ndarray:
    out = np.empty(len(a) + 1, np.int64)
    out[0] = 0
    np.cumsum(a, out=out[1:])
    return out


_DELIM_LUT_CACHE: dict[str, np.ndarray] = {}


def tokenize_batch(
    contents: list[str],
    vocab: "Vocab",
    max_len: int,
    *,
    delimiters: str = DEFAULT_DELIMITERS,
    tight: bool = True,
) -> TokenGrid:
    """Tokenize + vocab-encode a batch of contents in a few numpy passes.

    Byte-identical contract with the scalar path (property-tested): the
    returned ``ids``/``lens`` equal ``vocab.encode_batch([tokenize(c)[0]
    for c in contents], ...)`` run on a same-state vocab, including the
    id assignment order, and tokens/delims reconstruct ``tokenize``'s
    output exactly.

    Contents are joined with ``\\n`` (never a token or delimiter char);
    a content containing a newline — or one that defeats utf-8 encoding
    — routes the whole batch through the scalar reference path.
    """
    n = len(contents)
    if n == 0:
        return TokenGrid(np.zeros((0, 1), np.int32), np.zeros(0, np.int32),
                         np.zeros((0, 2), np.int32), [], b"",
                         np.zeros(0, np.int64), np.zeros(0, np.int64),
                         np.zeros(1, np.int64))
    try:
        if any("\n" in c for c in contents):
            raise ValueError
        data = "\n".join(contents).encode("utf-8", "surrogateescape")
    except (ValueError, UnicodeEncodeError):
        return _tokenize_batch_reference(contents, vocab, max_len,
                                         delimiters=delimiters, tight=tight)
    buf = np.frombuffer(data, np.uint8)
    lut = _DELIM_LUT_CACHE.get(delimiters)
    if lut is None:
        lut = class_mask(delimiters + "\n")
        _DELIM_LUT_CACHE[delimiters] = lut
    tok_mask = ~lut[buf]

    starts, ends = runs_of(tok_mask)
    line_starts = np.concatenate([[0], np.flatnonzero(buf == 0x0A) + 1])
    line_ends = np.concatenate([line_starts[1:] - 1, [len(buf)]])
    line_of = np.searchsorted(line_starts, starts, side="right") - 1
    lens = np.bincount(line_of, minlength=n).astype(np.int32)

    width = max_len
    if tight:
        width = max(1, min(max_len, int(lens.max(initial=1))))
    # replicate encode_batch's clipping: tokens at in-line position >= W
    # are never interned (their lines go verbatim), keeping vocab ids
    # identical to the scalar scan
    col = np.arange(len(starts)) - _cumsum0(lens)[line_of]
    keep = col < width
    fstarts, fends, fline, fcol = starts[keep], ends[keep], line_of[keep], col[keep]

    hasher = SegmentHasher(buf)
    tok_of, tok_table = intern_segments(data, hasher, fstarts, fends)
    vid = np.fromiter((vocab.id(t) for t in tok_table), np.int32,
                      count=len(tok_table)) if tok_table else np.zeros(0, np.int32)
    ids = np.zeros((n, width), dtype=np.int32)
    ids[fline, fcol] = vid[tok_of]

    # delimiter runs: per line [line_start, tok0), [tok_j_end, tok_j+1),
    # ..., [tok_m-1_end, tok_m) — min(lens, W) + 1 segments. Built from
    # the UNFILTERED token stream so a clipped line's last kept segment
    # ends at its next (clipped) token, exactly like the scalar path.
    m = np.minimum(lens, width).astype(np.int64)
    dptr = _cumsum0(m + 1)
    total = int(dptr[-1])
    ds = np.empty(total, np.int64)
    de = np.empty(total, np.int64)
    ds[dptr[:-1]] = line_starts
    de[dptr[:-1]] = line_ends  # overwritten below when the line has tokens
    if len(starts):
        first = col == 0
        de[dptr[line_of[first]]] = starts[first]
        nxt_same = np.empty(len(starts), bool)
        nxt_same[:-1] = line_of[1:] == line_of[:-1]
        nxt_same[-1] = False
        nxt_start = np.empty(len(starts), np.int64)
        nxt_start[:-1] = starts[1:]
        nxt_start[-1] = 0
        at = dptr[line_of[keep]] + 1 + col[keep]
        ds[at] = ends[keep]
        de[at] = np.where(nxt_same[keep], nxt_start[keep], line_ends[line_of[keep]])
    did, delim_table = intern_segments(data, hasher, ds, de)
    delim_ids = np.zeros((n, width + 1), np.int32)
    drow = np.repeat(np.arange(n), m + 1)
    delim_ids[drow, np.arange(total) - dptr[drow]] = did

    row_ptr = _cumsum0(np.minimum(lens, width))
    return TokenGrid(ids, lens, delim_ids, delim_table, data,
                     fstarts, fends, row_ptr)


def _tokenize_batch_reference(contents, vocab, max_len, *, delimiters, tight) -> TokenGrid:
    """Scalar fallback (and oracle): per-line tokenize + encode_batch,
    then the same interned-grid representation."""
    toks, delims = [], []
    for c in contents:
        t, d = tokenize(c, delimiters)
        toks.append(t)
        delims.append(d)
    ids, lens = vocab.encode_batch(toks, max_len, tight=tight)
    width = ids.shape[1]
    delim_ids = np.zeros((len(contents), width + 1), np.int32)
    delim_table: list[str] = []
    dmap: dict[str, int] = {}
    for u, d in enumerate(delims):
        for j, s in enumerate(d[:width + 1]):
            i = dmap.get(s)
            if i is None:
                i = len(delim_table)
                dmap[s] = i
                delim_table.append(s)
            delim_ids[u, j] = i
    # byte offsets against a private concatenation (identical substrings)
    enc = [c.encode("utf-8", "surrogateescape") for c in contents]
    data = b"\x00".join(enc)
    offs = _cumsum0(np.fromiter((len(e) + 1 for e in enc), np.int64, len(enc)))
    tok_starts: list[int] = []
    tok_ends: list[int] = []
    counts = np.minimum(lens, width)
    for u, (t, d) in enumerate(zip(toks, delims)):
        pos = int(offs[u]) + len(d[0].encode("utf-8", "surrogateescape"))
        for j in range(int(counts[u])):
            tb = len(t[j].encode("utf-8", "surrogateescape"))
            tok_starts.append(pos)
            tok_ends.append(pos + tb)
            pos += tb + len(d[j + 1].encode("utf-8", "surrogateescape"))
    return TokenGrid(ids, lens, delim_ids, delim_table, data,
                     np.asarray(tok_starts, np.int64), np.asarray(tok_ends, np.int64),
                     _cumsum0(counts))


@dataclass
class LogFormat:
    """loghub-style header format, e.g. ``<Date> <Time> <Level> <Component>: <Content>``."""

    format: str
    content_field: str = "Content"
    fields: list[str] = field(init=False)
    regex: re.Pattern = field(init=False)

    def __post_init__(self):
        self.fields = re.findall(r"<(\w+)>", self.format)
        if self.content_field not in self.fields:
            raise ValueError(f"format must contain <{self.content_field}>")
        pattern = ""
        pos = 0
        for m in re.finditer(r"<(\w+)>", self.format):
            lit = self.format[pos:m.start()]
            # whitespace in the format matches any whitespace run (captured
            # for losslessness via a separate group)
            pattern += re.escape(lit).replace(r"\ ", r"\s+")
            name = m.group(1)
            if name == self.content_field:
                pattern += rf"(?P<{name}>.*?)"
            else:
                pattern += rf"(?P<{name}>\S*?)"
            pos = m.end()
        pattern += re.escape(self.format[pos:]) + r"$"
        self.regex = re.compile("^" + pattern)
        # literal segments around the fields (in appearance order) so
        # render is one join instead of sequential str.replace passes
        self._segments = re.split(r"<\w+>", self.format)
        # split fast path (DESIGN.md §10): usable when the content field
        # is last, the format has no leading/trailing literals, and every
        # separator is "<core> " with a whitespace-free core — then the
        # regex + render round-trip is equivalent to one str.split plus
        # per-part suffix checks on the lines the fast path accepts;
        # anything irregular falls back to the regex per line.
        self._fast_cores: list[str] | None = None
        if (self.fields[-1] == self.content_field
                and self._segments[0] == "" and self._segments[-1] == ""):
            cores = []
            for seg in self._segments[1:-1]:
                if seg.endswith(" ") and not re.search(r"\s", seg[:-1]):
                    cores.append(seg[:-1])
                else:
                    break
            else:
                self._fast_cores = cores

    def parse(self, lines: list[str], *, fast: bool = True) -> tuple[dict[str, list[str]], list[int], list[int]]:
        """Parse lines -> (field columns, matched line idx, unmatched line idx).

        To keep the header losslessly reconstructible even with irregular
        whitespace, a matched line must round-trip through ``render``;
        otherwise it is treated as unmatched (stored verbatim).
        ``fast=False`` forces the regex reference path (oracle for the
        split fast path, which is property-tested to agree).
        """
        if fast and self._fast_cores is not None:
            return self._parse_fast(lines)
        cols: list[list[str]] = [[] for _ in self.fields]
        ok_idx: list[int] = []
        bad_idx: list[int] = []
        for i, line in enumerate(lines):
            vals = self._parse_regex_line(line)
            if vals is None:
                bad_idx.append(i)
                continue
            for c, v in zip(cols, vals):
                c.append(v)
            ok_idx.append(i)
        return dict(zip(self.fields, cols)), ok_idx, bad_idx

    def _parse_regex_line(self, line: str) -> tuple | None:
        m = self.regex.match(line)
        if m is None:
            return None
        vals = m.groups()  # named groups appear in field order
        segs = self._segments
        rendered = segs[0]
        for v, seg in zip(vals, segs[1:]):
            rendered += v + seg
        return vals if rendered == line else None

    def _parse_fast(self, lines: list[str]) -> tuple[dict[str, list[str]], list[int], list[int]]:
        """One ``str.split`` per line for regular lines; regex fallback
        for anything suspicious (empty parts = multi-space runs, other
        whitespace, non-ASCII header fields, missing separator cores).

        The fast accept is a strict subset of the regex accept with
        identical captures: split parts are maximal space-free runs, and
        within such a run the regex's non-greedy field + literal core +
        ``\\s+`` can only bind the core as the run's suffix.
        """
        cores = self._fast_cores
        nsep = len(cores)
        rows: list[tuple] = []
        ok_idx: list[int] = []
        bad_idx: list[int] = []
        for i, line in enumerate(lines):
            parts = line.split(" ", nsep)
            ok = len(parts) == nsep + 1 and "\n" not in parts[nsep]
            if ok:
                for j in range(nsep):
                    p = parts[j]
                    if not (p and p.isascii() and p.isprintable()):
                        ok = False
                        break
                    c = cores[j]
                    if c:
                        if not p.endswith(c):
                            ok = False
                            break
                        parts[j] = p[:-len(c)]
            if ok:
                rows.append(tuple(parts))
                ok_idx.append(i)
                continue
            vals = self._parse_regex_line(line)
            if vals is None:
                bad_idx.append(i)
            else:
                rows.append(vals)
                ok_idx.append(i)
        cols = [list(c) for c in zip(*rows)] if rows else [[] for _ in self.fields]
        return dict(zip(self.fields, cols)), ok_idx, bad_idx

    def render(self, values: dict[str, str]) -> str:
        out = [self._segments[0]]
        for f, seg in zip(self.fields, self._segments[1:]):
            out.append(values[f])
            out.append(seg)
        return "".join(out)


# Formats for the five paper datasets (loghub conventions).
LOG_FORMATS: dict[str, LogFormat] = {
    "HDFS": LogFormat("<Date> <Time> <Pid> <Level> <Component>: <Content>"),
    "Spark": LogFormat("<Date> <Time> <Level> <Component>: <Content>"),
    "Android": LogFormat("<Date> <Time> <Pid> <Tid> <Level> <Component>: <Content>"),
    "Windows": LogFormat("<Date> <Time>, <Level> <Component> <Content>"),
    "Thunderbird": LogFormat("<Label> <Timestamp> <Date> <User> <Month> <Day> <Time> <Location> <Component>: <Content>"),
}


class Vocab:
    """Token-string <-> int32 id mapping. 0=PAD, 1=STAR ('*')."""

    def __init__(self):
        self._to_id: dict[str, int] = {}
        self._to_str: list[str] = ["\x00PAD", "*"]

    def __len__(self) -> int:
        return len(self._to_str)

    def id(self, token: str) -> int:
        """Get-or-assign id for a token. Literal '*' is escaped."""
        if token == "*":
            token = "\x01*"
        i = self._to_id.get(token)
        if i is None:
            i = len(self._to_str)
            self._to_id[token] = i
            self._to_str.append(token)
        return i

    def lookup(self, token: str) -> int:
        """Id for a token or PAD_ID if unseen (never assigns)."""
        if token == "*":
            token = "\x01*"
        return self._to_id.get(token, PAD_ID)

    def token(self, i: int) -> str:
        t = self._to_str[i]
        return "*" if t == "\x01*" else t

    def encode_batch(
        self, token_lists: list[list[str]], max_len: int, *, assign: bool = True,
        tight: bool = False,
    ) -> tuple[np.ndarray, np.ndarray]:
        """-> (ids (N, W) int32 PAD-padded, lengths (N,) int32).

        ``W = max_len`` normally; with ``tight=True`` the width shrinks to
        the actual longest line (capped at ``max_len``) so downstream DP
        matching pays for observed lengths, not the budget. Lines longer
        than ``max_len`` get length = actual length (callers treat
        len > max_len as unmatched / verbatim).

        Single-pass: tokens are flattened, interned once per *distinct*
        token (id assignment keeps first-occurrence order, identical to a
        per-token scan), and scattered into the padded matrix with numpy.
        """
        n = len(token_lists)
        lens = np.fromiter((len(t) for t in token_lists), np.int32, count=n)
        width = max_len
        if tight:
            width = max(1, min(max_len, int(lens.max(initial=1))))
        clens = np.minimum(lens, width)
        ids = np.zeros((n, width), dtype=np.int32)
        total = int(clens.sum())
        if total == 0:
            return ids, lens
        flat: list[str] = []
        for toks, c in zip(token_lists, clens):
            flat.extend(toks if len(toks) <= width else toks[:c])
        flat_ids = np.empty(total, np.int32)
        if assign:
            to_id, to_str = self._to_id, self._to_str
            for i, t in enumerate(flat):
                if t == "*":
                    t = "\x01*"
                v = to_id.get(t)
                if v is None:
                    v = len(to_str)
                    to_id[t] = v
                    to_str.append(t)
                flat_ids[i] = v
        else:
            get = self._to_id.get
            for i, t in enumerate(flat):
                flat_ids[i] = get("\x01*" if t == "*" else t, PAD_ID)
        rows = np.repeat(np.arange(n), clens)
        starts = np.cumsum(clens) - clens
        cols = np.arange(total) - np.repeat(starts, clens)
        ids[rows, cols] = flat_ids
        return ids, lens
