"""Log line parsing + content tokenization for logzip (paper §II, §IV-B L1).

A ``LogFormat`` turns a loghub-style format string, e.g.::

    "<Date> <Time> <Level> <Component>: <Content>"

into a compiled regex with named groups (same convention as logparser /
the original logzip). ``parse`` splits every raw line into header-field
columns plus the free-text message content; lines that do not match the
format are routed to a verbatim side-channel so compression stays lossless.

``tokenize`` splits message content into (tokens, delimiters) where the
delimiter strings are preserved exactly: ``reassemble(tokens, delims)``
is byte-identical to the input. Matching/clustering operate on tokens
only; delimiters ride along in a pattern-dictionary column.

``Vocab`` maps token strings to int32 ids for the accelerator path.
id 0 is PAD, id 1 is the wildcard ``*`` (never produced by tokenize:
literal "*" tokens are escaped on entry).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

PAD_ID = 0
STAR_ID = 1
_N_RESERVED = 2

# Token delimiters used by the paper's implementation: whitespace plus a
# small set of punctuation. A "token" is a maximal run of non-delimiter
# characters; delimiter runs are preserved verbatim.
DEFAULT_DELIMITERS = " \t,;:="
_TOKEN_RE_CACHE: dict[str, re.Pattern] = {}


def _token_re(delimiters: str) -> re.Pattern:
    pat = _TOKEN_RE_CACHE.get(delimiters)
    if pat is None:
        cls = re.escape(delimiters)
        pat = re.compile(rf"[^{cls}]+|[{cls}]+")
        _TOKEN_RE_CACHE[delimiters] = pat
    return pat


def tokenize(content: str, delimiters: str = DEFAULT_DELIMITERS) -> tuple[list[str], list[str]]:
    """Split ``content`` into (tokens, delims).

    ``len(delims) == len(tokens) + 1``; delims[0] / delims[-1] are the
    (possibly empty) leading / trailing delimiter runs.
    """
    tokens: list[str] = []
    delims: list[str] = [""]
    if not content:
        return tokens, delims
    dset = set(delimiters)
    # findall yields maximal alternating runs of token / delimiter chars.
    for piece in _token_re(delimiters).findall(content):
        if piece[0] in dset:
            delims[-1] += piece
        else:
            tokens.append(piece)
            delims.append("")
    return tokens, delims


def reassemble(tokens: list[str], delims: list[str]) -> str:
    out = [delims[0]]
    for t, d in zip(tokens, delims[1:]):
        out.append(t)
        out.append(d)
    return "".join(out)


@dataclass
class LogFormat:
    """loghub-style header format, e.g. ``<Date> <Time> <Level> <Component>: <Content>``."""

    format: str
    content_field: str = "Content"
    fields: list[str] = field(init=False)
    regex: re.Pattern = field(init=False)

    def __post_init__(self):
        self.fields = re.findall(r"<(\w+)>", self.format)
        if self.content_field not in self.fields:
            raise ValueError(f"format must contain <{self.content_field}>")
        pattern = ""
        pos = 0
        for m in re.finditer(r"<(\w+)>", self.format):
            lit = self.format[pos:m.start()]
            # whitespace in the format matches any whitespace run (captured
            # for losslessness via a separate group)
            pattern += re.escape(lit).replace(r"\ ", r"\s+")
            name = m.group(1)
            if name == self.content_field:
                pattern += rf"(?P<{name}>.*?)"
            else:
                pattern += rf"(?P<{name}>\S*?)"
            pos = m.end()
        pattern += re.escape(self.format[pos:]) + r"$"
        self.regex = re.compile("^" + pattern)

    def parse(self, lines: list[str]) -> tuple[dict[str, list[str]], list[int], list[int]]:
        """Parse lines -> (field columns, matched line idx, unmatched line idx).

        To keep the header losslessly reconstructible even with irregular
        whitespace, a matched line must round-trip through ``render``;
        otherwise it is treated as unmatched (stored verbatim).
        """
        columns: dict[str, list[str]] = {f: [] for f in self.fields}
        ok_idx: list[int] = []
        bad_idx: list[int] = []
        for i, line in enumerate(lines):
            m = self.regex.match(line)
            if m is None:
                bad_idx.append(i)
                continue
            vals = m.groupdict()
            if self.render(vals) != line:
                bad_idx.append(i)
                continue
            for f in self.fields:
                columns[f].append(vals[f])
            ok_idx.append(i)
        return columns, ok_idx, bad_idx

    def render(self, values: dict[str, str]) -> str:
        out = self.format
        for f in self.fields:
            out = out.replace(f"<{f}>", values[f], 1)
        return out


# Formats for the five paper datasets (loghub conventions).
LOG_FORMATS: dict[str, LogFormat] = {
    "HDFS": LogFormat("<Date> <Time> <Pid> <Level> <Component>: <Content>"),
    "Spark": LogFormat("<Date> <Time> <Level> <Component>: <Content>"),
    "Android": LogFormat("<Date> <Time> <Pid> <Tid> <Level> <Component>: <Content>"),
    "Windows": LogFormat("<Date> <Time>, <Level> <Component> <Content>"),
    "Thunderbird": LogFormat("<Label> <Timestamp> <Date> <User> <Month> <Day> <Time> <Location> <Component>: <Content>"),
}


class Vocab:
    """Token-string <-> int32 id mapping. 0=PAD, 1=STAR ('*')."""

    def __init__(self):
        self._to_id: dict[str, int] = {}
        self._to_str: list[str] = ["\x00PAD", "*"]

    def __len__(self) -> int:
        return len(self._to_str)

    def id(self, token: str) -> int:
        """Get-or-assign id for a token. Literal '*' is escaped."""
        if token == "*":
            token = "\x01*"
        i = self._to_id.get(token)
        if i is None:
            i = len(self._to_str)
            self._to_id[token] = i
            self._to_str.append(token)
        return i

    def lookup(self, token: str) -> int:
        """Id for a token or PAD_ID if unseen (never assigns)."""
        if token == "*":
            token = "\x01*"
        return self._to_id.get(token, PAD_ID)

    def token(self, i: int) -> str:
        t = self._to_str[i]
        return "*" if t == "\x01*" else t

    def encode_batch(
        self, token_lists: list[list[str]], max_len: int, *, assign: bool = True
    ) -> tuple[np.ndarray, np.ndarray]:
        """-> (ids (N, max_len) int32 PAD-padded, lengths (N,) int32).

        Lines longer than ``max_len`` get length = actual length (callers
        treat len > max_len as unmatched / verbatim).
        """
        n = len(token_lists)
        ids = np.zeros((n, max_len), dtype=np.int32)
        lens = np.zeros((n,), dtype=np.int32)
        get = self.id if assign else self.lookup
        for r, toks in enumerate(token_lists):
            lens[r] = len(toks)
            for c, t in enumerate(toks[:max_len]):
                ids[r, c] = get(t)
        return ids, lens
