"""Log line parsing + content tokenization for logzip (paper §II, §IV-B L1).

A ``LogFormat`` turns a loghub-style format string, e.g.::

    "<Date> <Time> <Level> <Component>: <Content>"

into a compiled regex with named groups (same convention as logparser /
the original logzip). ``parse`` splits every raw line into header-field
columns plus the free-text message content; lines that do not match the
format are routed to a verbatim side-channel so compression stays lossless.

``tokenize`` splits message content into (tokens, delimiters) where the
delimiter strings are preserved exactly: ``reassemble(tokens, delims)``
is byte-identical to the input. Matching/clustering operate on tokens
only; delimiters ride along in a pattern-dictionary column.

``Vocab`` maps token strings to int32 ids for the accelerator path.
id 0 is PAD, id 1 is the wildcard ``*`` (never produced by tokenize:
literal "*" tokens are escaped on entry).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

PAD_ID = 0
STAR_ID = 1
_N_RESERVED = 2

# Token delimiters used by the paper's implementation: whitespace plus a
# small set of punctuation. A "token" is a maximal run of non-delimiter
# characters; delimiter runs are preserved verbatim.
DEFAULT_DELIMITERS = " \t,;:="
_TOKEN_RE_CACHE: dict[str, re.Pattern] = {}


def _token_re(delimiters: str) -> re.Pattern:
    pat = _TOKEN_RE_CACHE.get(delimiters)
    if pat is None:
        cls = re.escape(delimiters)
        pat = re.compile(rf"[^{cls}]+")
        _TOKEN_RE_CACHE[delimiters] = pat
    return pat


def tokenize(content: str, delimiters: str = DEFAULT_DELIMITERS) -> tuple[list[str], list[str]]:
    """Split ``content`` into (tokens, delims).

    ``len(delims) == len(tokens) + 1``; delims[0] / delims[-1] are the
    (possibly empty) leading / trailing delimiter runs.
    """
    # Two C-level regex passes instead of a Python loop over runs:
    # findall gives the maximal token runs, split gives the delimiter runs
    # around them (including the possibly-empty leading/trailing runs).
    pat = _token_re(delimiters)
    return pat.findall(content), pat.split(content)


def reassemble(tokens: list[str], delims: list[str]) -> str:
    out = [delims[0]]
    for t, d in zip(tokens, delims[1:]):
        out.append(t)
        out.append(d)
    return "".join(out)


@dataclass
class LogFormat:
    """loghub-style header format, e.g. ``<Date> <Time> <Level> <Component>: <Content>``."""

    format: str
    content_field: str = "Content"
    fields: list[str] = field(init=False)
    regex: re.Pattern = field(init=False)

    def __post_init__(self):
        self.fields = re.findall(r"<(\w+)>", self.format)
        if self.content_field not in self.fields:
            raise ValueError(f"format must contain <{self.content_field}>")
        pattern = ""
        pos = 0
        for m in re.finditer(r"<(\w+)>", self.format):
            lit = self.format[pos:m.start()]
            # whitespace in the format matches any whitespace run (captured
            # for losslessness via a separate group)
            pattern += re.escape(lit).replace(r"\ ", r"\s+")
            name = m.group(1)
            if name == self.content_field:
                pattern += rf"(?P<{name}>.*?)"
            else:
                pattern += rf"(?P<{name}>\S*?)"
            pos = m.end()
        pattern += re.escape(self.format[pos:]) + r"$"
        self.regex = re.compile("^" + pattern)
        # literal segments around the fields (in appearance order) so
        # render is one join instead of sequential str.replace passes
        self._segments = re.split(r"<\w+>", self.format)

    def parse(self, lines: list[str]) -> tuple[dict[str, list[str]], list[int], list[int]]:
        """Parse lines -> (field columns, matched line idx, unmatched line idx).

        To keep the header losslessly reconstructible even with irregular
        whitespace, a matched line must round-trip through ``render``;
        otherwise it is treated as unmatched (stored verbatim).
        """
        cols: list[list[str]] = [[] for _ in self.fields]
        ok_idx: list[int] = []
        bad_idx: list[int] = []
        segs = self._segments
        match = self.regex.match
        for i, line in enumerate(lines):
            m = match(line)
            if m is None:
                bad_idx.append(i)
                continue
            vals = m.groups()  # named groups appear in field order
            rendered = segs[0]
            for v, seg in zip(vals, segs[1:]):
                rendered += v + seg
            if rendered != line:
                bad_idx.append(i)
                continue
            for c, v in zip(cols, vals):
                c.append(v)
            ok_idx.append(i)
        return dict(zip(self.fields, cols)), ok_idx, bad_idx

    def render(self, values: dict[str, str]) -> str:
        out = [self._segments[0]]
        for f, seg in zip(self.fields, self._segments[1:]):
            out.append(values[f])
            out.append(seg)
        return "".join(out)


# Formats for the five paper datasets (loghub conventions).
LOG_FORMATS: dict[str, LogFormat] = {
    "HDFS": LogFormat("<Date> <Time> <Pid> <Level> <Component>: <Content>"),
    "Spark": LogFormat("<Date> <Time> <Level> <Component>: <Content>"),
    "Android": LogFormat("<Date> <Time> <Pid> <Tid> <Level> <Component>: <Content>"),
    "Windows": LogFormat("<Date> <Time>, <Level> <Component> <Content>"),
    "Thunderbird": LogFormat("<Label> <Timestamp> <Date> <User> <Month> <Day> <Time> <Location> <Component>: <Content>"),
}


class Vocab:
    """Token-string <-> int32 id mapping. 0=PAD, 1=STAR ('*')."""

    def __init__(self):
        self._to_id: dict[str, int] = {}
        self._to_str: list[str] = ["\x00PAD", "*"]

    def __len__(self) -> int:
        return len(self._to_str)

    def id(self, token: str) -> int:
        """Get-or-assign id for a token. Literal '*' is escaped."""
        if token == "*":
            token = "\x01*"
        i = self._to_id.get(token)
        if i is None:
            i = len(self._to_str)
            self._to_id[token] = i
            self._to_str.append(token)
        return i

    def lookup(self, token: str) -> int:
        """Id for a token or PAD_ID if unseen (never assigns)."""
        if token == "*":
            token = "\x01*"
        return self._to_id.get(token, PAD_ID)

    def token(self, i: int) -> str:
        t = self._to_str[i]
        return "*" if t == "\x01*" else t

    def encode_batch(
        self, token_lists: list[list[str]], max_len: int, *, assign: bool = True,
        tight: bool = False,
    ) -> tuple[np.ndarray, np.ndarray]:
        """-> (ids (N, W) int32 PAD-padded, lengths (N,) int32).

        ``W = max_len`` normally; with ``tight=True`` the width shrinks to
        the actual longest line (capped at ``max_len``) so downstream DP
        matching pays for observed lengths, not the budget. Lines longer
        than ``max_len`` get length = actual length (callers treat
        len > max_len as unmatched / verbatim).

        Single-pass: tokens are flattened, interned once per *distinct*
        token (id assignment keeps first-occurrence order, identical to a
        per-token scan), and scattered into the padded matrix with numpy.
        """
        n = len(token_lists)
        lens = np.fromiter((len(t) for t in token_lists), np.int32, count=n)
        width = max_len
        if tight:
            width = max(1, min(max_len, int(lens.max(initial=1))))
        clens = np.minimum(lens, width)
        ids = np.zeros((n, width), dtype=np.int32)
        total = int(clens.sum())
        if total == 0:
            return ids, lens
        flat: list[str] = []
        for toks, c in zip(token_lists, clens):
            flat.extend(toks if len(toks) <= width else toks[:c])
        flat_ids = np.empty(total, np.int32)
        if assign:
            to_id, to_str = self._to_id, self._to_str
            for i, t in enumerate(flat):
                if t == "*":
                    t = "\x01*"
                v = to_id.get(t)
                if v is None:
                    v = len(to_str)
                    to_id[t] = v
                    to_str.append(t)
                flat_ids[i] = v
        else:
            get = self._to_id.get
            for i, t in enumerate(flat):
                flat_ids[i] = get("\x01*" if t == "*" else t, PAD_ID)
        rows = np.repeat(np.arange(n), clens)
        starts = np.cumsum(clens) - clens
        cols = np.arange(total) - np.repeat(starts, clens)
        ids[rows, cols] = flat_ids
        return ids, lens
