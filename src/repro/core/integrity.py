"""Frame integrity for v3 archives (DESIGN.md §13): CRC32C trailers and
the structured ``IntegrityError``.

Every frame a v3 container writes — the LZJF kernel blob, the LZJS
session header, per-chunk payload / template-delta / ParamDict-delta
frames, the commit record and the footer index — is followed by a
4-byte little-endian CRC32C (Castagnoli) of the frame bytes.  Readers
verify on touch and raise ``IntegrityError`` carrying *which* frame
failed, at *which* byte offset, in *which* chunk — the difference
between "archive corrupt" and an actionable fsck report.

CRC32C (not zlib's CRC-32/ISO-HDLC) because it is the storage-stack
convention (iSCSI, ext4, btrfs, leveldb): a torn write that splices two
archives generated with the same tooling still fails the check, and
hardware-accelerated verification is available everywhere this format
could be re-implemented.  Large frames take a numpy-vectorized path
(independent per-block table CRCs + a log-depth GF(2) fold), small ones
a slicing-by-16 scalar loop — either way checksumming stays invisible
next to the entropy kernel.
"""

from __future__ import annotations

import struct

CRC_LEN = 4  # trailer size in bytes

_POLY = 0x82F63B78  # CRC-32C (Castagnoli), reflected


def _build_tables() -> list[list[int]]:
    t0 = []
    for i in range(256):
        c = i
        for _ in range(8):
            c = (c >> 1) ^ (_POLY if c & 1 else 0)
        t0.append(c)
    tables = [t0]
    for _ in range(15):
        prev = tables[-1]
        tables.append([t0[v & 0xFF] ^ (v >> 8) for v in prev])
    return tables


_T = _build_tables()
_U16 = struct.Struct("<QQ")


def _crc_scalar(data, crc: int = 0) -> int:
    """Slicing-by-16 CRC-32C, continuing from ``crc``."""
    crc = ~crc & 0xFFFFFFFF
    mv = memoryview(data)
    n = len(mv)
    i = 0
    t = _T
    # slicing-by-16: fold the running crc into the first word, then
    # 16 independent table lookups per iteration
    end16 = n - (n % 16)
    while i < end16:
        lo, hi = _U16.unpack_from(mv, i)
        lo ^= crc
        crc = (
            t[15][lo & 0xFF] ^ t[14][(lo >> 8) & 0xFF]
            ^ t[13][(lo >> 16) & 0xFF] ^ t[12][(lo >> 24) & 0xFF]
            ^ t[11][(lo >> 32) & 0xFF] ^ t[10][(lo >> 40) & 0xFF]
            ^ t[9][(lo >> 48) & 0xFF] ^ t[8][(lo >> 56) & 0xFF]
            ^ t[7][hi & 0xFF] ^ t[6][(hi >> 8) & 0xFF]
            ^ t[5][(hi >> 16) & 0xFF] ^ t[4][(hi >> 24) & 0xFF]
            ^ t[3][(hi >> 32) & 0xFF] ^ t[2][(hi >> 40) & 0xFF]
            ^ t[1][(hi >> 48) & 0xFF] ^ t[0][(hi >> 56) & 0xFF]
        )
        i += 16
    t0 = t[0]
    while i < n:
        crc = t0[(crc ^ mv[i]) & 0xFF] ^ (crc >> 8)
        i += 1
    return ~crc & 0xFFFFFFFF


# ------------------------------------------------- vectorized bulk path
#
# CRC is GF(2)-linear in the message: with zero initial state,
# raw(A || B) = shift_{|B|}(raw(A)) ^ raw(B), where shift_L is the linear
# map "multiply by x^{8L} mod P".  The bulk path computes the raw CRC of
# every 16-byte block with pure table XORs (numpy, no data dependence),
# then folds pairs level by level — shift_L at each level is applied to
# the whole vector of partial CRCs through four 256-entry tables.  A
# Python loop therefore runs O(log n) vector steps instead of O(n/16)
# scalar steps.  Equality with ``_crc_scalar`` is property-tested.

_NPT = None          # (16, 256) uint32: per-position block tables
_SHIFT_TABLES: dict[int, object] = {}   # L bytes -> (4, 256) uint32 map


def _gf2_times(mat: list[int], vec: int) -> int:
    out = 0
    i = 0
    while vec:
        if vec & 1:
            out ^= mat[i]
        vec >>= 1
        i += 1
    return out


def _gf2_square(mat: list[int]) -> list[int]:
    return [_gf2_times(mat, mat[i]) for i in range(32)]


_SHIFT_MATS: dict[int, list[int]] = {}


def _shift_matrix(nbytes: int) -> list[int]:
    """Columns of the linear map ``state -> state after nbytes zero bytes``."""
    out = _SHIFT_MATS.get(nbytes)
    if out is None:
        # one zero byte: state -> T0[state & 0xFF] ^ (state >> 8)
        mat = [_T[0][(1 << i) & 0xFF] ^ ((1 << i) >> 8) for i in range(32)]
        out = [1 << i for i in range(32)]  # identity
        n = nbytes
        while n:
            if n & 1:
                out = [_gf2_times(mat, out[i]) for i in range(32)]
            mat = _gf2_square(mat)
            n >>= 1
        _SHIFT_MATS[nbytes] = out
    return out


def _shift_table(nbytes: int):
    """(4, 256) uint32 tables applying ``_shift_matrix(nbytes)`` to a
    uint32 vector byte-by-byte."""
    import numpy as np

    tab = _SHIFT_TABLES.get(nbytes)
    if tab is None:
        mat = _shift_matrix(nbytes)
        tab = np.zeros((4, 256), np.uint32)
        for b in range(4):
            base = [mat[8 * b + i] for i in range(8)]
            row = tab[b]
            for v in range(256):
                acc = 0
                vv = v
                i = 0
                while vv:
                    if vv & 1:
                        acc ^= base[i]
                    vv >>= 1
                    i += 1
                row[v] = acc
        _SHIFT_TABLES[nbytes] = tab
    return tab


def _shift_vec(crcs, nbytes: int):
    tab = _shift_table(nbytes)
    return (tab[0][crcs & 0xFF] ^ tab[1][(crcs >> 8) & 0xFF]
            ^ tab[2][(crcs >> 16) & 0xFF] ^ tab[3][crcs >> 24])


def _crc_bulk(data, crc: int = 0) -> int:
    import numpy as np

    global _NPT
    if _NPT is None:
        _NPT = np.asarray(_T, np.uint32)
    n = len(data)
    m = n // 16
    head = m * 16
    arr = np.frombuffer(data, np.uint8, count=head).reshape(m, 16)
    bc = _NPT[15][arr[:, 0]]
    for j in range(1, 16):
        bc ^= _NPT[15 - j][arr[:, j]]
    # fold the initial state into the first block — same as the scalar
    # loop's ``lo ^= crc``, expressed through the position tables
    init = ~crc & 0xFFFFFFFF
    bc[0] ^= (_NPT[15][init & 0xFF] ^ _NPT[14][(init >> 8) & 0xFF]
              ^ _NPT[13][(init >> 16) & 0xFF] ^ _NPT[12][init >> 24])
    # pad the FRONT to a power of two: leading zero blocks leave a
    # zero-state raw CRC unchanged, so the fold lengths stay uniform
    m2 = 1 << (m - 1).bit_length()
    if m2 != m:
        bc = np.concatenate([np.zeros(m2 - m, np.uint32), bc])
    level = 16
    while len(bc) > 1:
        bc = _shift_vec(bc[0::2], level) ^ bc[1::2]
        level *= 2
    state = int(bc[0])
    t0 = _T[0]
    for b in memoryview(data)[head:]:
        state = t0[(state ^ b) & 0xFF] ^ (state >> 8)
    return ~state & 0xFFFFFFFF


def crc32c(data, crc: int = 0) -> int:
    """CRC-32C of ``data``, continuing from ``crc`` (chainable)."""
    if len(data) >= 512:
        return _crc_bulk(data, crc)
    return _crc_scalar(data, crc)


def trailer(data: bytes) -> bytes:
    """The 4-byte little-endian CRC32C trailer for one frame."""
    return crc32c(data).to_bytes(CRC_LEN, "little")


class IntegrityError(ValueError):
    """A frame failed its CRC32C check (or a sealed commit record is
    missing/invalid).

    Subclasses ``ValueError`` so every pre-v3 caller that guards decode
    paths with ``except ValueError`` keeps working; carries structured
    fields so fsck / salvage tooling can report and quarantine precisely.

    Attributes: ``frame`` (e.g. ``"chunk_payload"``, ``"template_delta"``,
    ``"footer"``), ``offset`` (byte position of the frame in the
    container, when known) and ``chunk`` (chunk index, when applicable).
    """

    def __init__(self, message: str, *, frame: str, offset: int | None = None,
                 chunk: int | None = None):
        loc = f" frame={frame}"
        if chunk is not None:
            loc += f" chunk={chunk}"
        if offset is not None:
            loc += f" offset={offset}"
        super().__init__(f"{message} [{loc.strip()}]")
        self.frame = frame
        self.offset = offset
        self.chunk = chunk


def verify(data: bytes, stored: bytes, *, frame: str, offset: int | None = None,
           chunk: int | None = None) -> None:
    """Check ``data`` against its stored trailer; raise ``IntegrityError``
    on mismatch (including a short/missing trailer)."""
    if len(stored) != CRC_LEN:
        raise IntegrityError(
            f"missing CRC32C trailer ({len(stored)}/{CRC_LEN} bytes)",
            frame=frame, offset=offset, chunk=chunk)
    got = crc32c(data)
    want = int.from_bytes(stored, "little")
    if got != want:
        raise IntegrityError(
            f"CRC32C mismatch: computed {got:#010x}, stored {want:#010x}",
            frame=frame, offset=offset, chunk=chunk)
