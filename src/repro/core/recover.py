"""Crash recovery for LZJS containers (DESIGN.md §13): salvage scanning,
``fsck`` and ``repair``.

The v3 commit record is the anchor: it is CRC-sealed, self-locating
(carries the absolute record offset) and self-framing (carries the three
frame lengths), so scanning the raw bytes for valid ``CMT1`` records
rebuilds the chunk index with no footer at all. From there:

- **fsck** verifies every frame of every located chunk and reports,
  without touching the file: which chunks are intact, which are
  quarantined (content checksum failures), and which line ranges are
  lost (chunks whose commit never hit the disk were, by definition,
  never committed).
- **repair** additionally *restores* record envelopes — the CHNK magic,
  length varints and commit bytes are all derivable from trusted
  metadata, so a bit flip there is healed in place rather than costing
  the chunk — then test-decodes every survivor against the accumulated
  dictionaries and rewrites a fresh footer (quarantine marks included)
  at the end of the last committed record. After repair the container
  opens with the ordinary ``LZJSReader``; quarantined chunks read as
  missing line ranges, everything else reads normally.

v1/v2 containers (no checksums, no commits) get best-effort sequential
recovery: records are walked from the header and each chunk is decoded
to establish its line range; the walk stops at the first record that no
longer parses.
"""

from __future__ import annotations

import json
import os
import zlib

from . import integrity
from .codec import KERNEL_BY_ID
from .encode import split_column
from .integrity import CRC_LEN
from .screens import skip_opt_frames
from .stream import (
    CHUNK_MAGIC,
    COMMIT_MAGIC,
    FOOTER_MAGIC,
    READ_VERSIONS,
    STREAM_MAGIC,
    V3,
    LZJSReader,
    _take_varint,
    _varint_bytes,
    build_commit,
    frame_positions,
    parse_chunk_record,
    parse_commit,
)

_FRAMES = ("chunk_payload", "template_delta", "paramdict_delta")


# ------------------------------------------------------------- structure

def _parse_header(data: bytes):
    """-> (version, header_dict, header_end, ok). Never raises on damage:
    a broken header degrades to ``({}, ok=False)`` — chunks that do not
    reference seed templates/params still decode."""
    if len(data) < 5 or data[:4] != STREAM_MAGIC:
        raise ValueError(
            f"not an LZJS container: magic {bytes(data[:4])!r}, "
            f"expected {STREAM_MAGIC!r}")
    version = data[4]
    if version not in READ_VERSIONS:
        raise ValueError(f"LZJS container version {version} is newer than "
                         f"this reader (supports 1..{V3})")
    try:
        hlen, pos = _take_varint(data, 5)
        hblob = data[pos:pos + hlen]
        if len(hblob) != hlen:
            raise ValueError("truncated header")
        end = pos + hlen
        if version >= V3:
            integrity.verify(data[:end], bytes(data[end:end + CRC_LEN]),
                             frame="header", offset=0)
            end += CRC_LEN
        header = json.loads(zlib.decompress(hblob).decode("utf-8"))
        return version, header, end, True
    except ValueError:
        return version, {}, 5, False


def _parse_footer(data: bytes, version: int):
    """-> (footer_dict, footer_offset); raises ValueError on any damage."""
    end = len(data)
    if end < 16 or data[end - 8:] != FOOTER_MAGIC:
        raise ValueError("footer magic missing")
    flen = int.from_bytes(data[end - 16:end - 8], "little")
    extra = CRC_LEN if version >= V3 else 0
    if flen + 16 + extra > end:
        raise ValueError("footer length out of range")
    off = end - 16 - extra - flen
    if version >= V3:
        integrity.verify(data[off:off + flen],
                         bytes(data[off + flen:off + flen + CRC_LEN]),
                         frame="footer", offset=off)
    try:
        return json.loads(zlib.decompress(data[off:off + flen]).decode("utf-8")), off
    except Exception as e:
        raise ValueError(f"corrupt footer: {e}") from e


def _entry_from_commit(c: dict, end: int) -> dict:
    g = (c["blob_len"], c["td_len"], c["pd_len"])
    doffset = c["offset"] + 4 + len(_varint_bytes(c["blob_len"])) \
        + c["blob_len"] + CRC_LEN
    return {
        "offset": c["offset"], "length": end - c["offset"], "doffset": doffset,
        "line_start": c["line_start"], "n_lines": c["n_lines"],
        "tpl_base": c["tpl_base"], "n_delta": c["n_delta"],
        "pd_base": c["pd_base"], "pd_delta": c["pd_delta"],
        "match_rate": 0.0, "manifest": None, "g": list(g),
    }


def scan_commits(data: bytes) -> list[dict]:
    """Find every sealed commit record and return the chunk index entries
    it vouches for, sorted by offset. A commit only counts when its CRC
    verifies AND its self-declared geometry places it exactly where it
    was found — stray ``CMT1`` byte patterns inside compressed payloads
    fail one or the other."""
    entries: dict[int, dict] = {}
    pos = data.find(COMMIT_MAGIC)
    while pos != -1:
        got = parse_commit(data, pos)
        if got is not None:
            c, end = got
            expected = c["offset"] + frame_positions(
                c["blob_len"], c["td_len"], c["pd_len"])[3]
            if expected == pos and c["offset"] >= 5:
                entries[c["offset"]] = _entry_from_commit(c, end)
                pos = data.find(COMMIT_MAGIC, end)
                continue
        pos = data.find(COMMIT_MAGIC, pos + 1)
    return [entries[o] for o in sorted(entries)]


def _scan_sequential(data: bytes, start: int, header: dict) -> list[dict]:
    """v1/v2 best-effort: walk records from the header, decode each chunk
    to establish its line range; stop at the first structural failure."""
    from .codec import _deserialize_template, decompress

    templates = [tuple(t) for t in header.get("seed_templates", [])]
    params = list(header.get("seed_params", []))
    entries: list[dict] = []
    pos, line = start, 0
    while data[pos:pos + 4] == CHUNK_MAGIC:
        try:
            off = pos
            bl, p = _take_varint(data, pos + 4)
            blob = data[p:p + bl]
            if len(blob) != bl:
                break
            doffset = p + bl
            tl, p = _take_varint(data, doffset)
            td = data[p:p + tl]
            p += tl
            pl, p = _take_varint(data, p)
            pd = data[p:p + pl]
            if len(td) != tl or len(pd) != pl:
                break
            p += pl
            tpl_base, pd_base = len(templates), len(params)
            new_t = [tuple(_deserialize_template(s))
                     for s in split_column(zlib.decompress(td))]
            new_p = split_column(zlib.decompress(pd))
            templates.extend(new_t)
            params.extend(new_p)
            lines = decompress(blob, ext_templates=templates, ext_params=params)
        except Exception:
            break
        entries.append({
            "offset": off, "length": p - off, "doffset": doffset,
            "line_start": line, "n_lines": len(lines),
            "tpl_base": tpl_base, "n_delta": len(new_t),
            "pd_base": pd_base, "pd_delta": len(new_p),
            "match_rate": 0.0, "manifest": None,
        })
        line += len(lines)
        pos = p
    return entries


def _has_unclaimed(data: bytes, start: int, index: list[dict]) -> bool:
    """True when a CHNK record sits in a byte range no entry claims —
    the double-fault case (commit AND footer both damaged)."""
    pos = start
    for e in index:
        if e["offset"] != pos:
            return True
        # commit-derived lengths stop at the commit; footer lengths span
        # any optional post-commit frames (SCRN) too — skip either way
        pos = skip_opt_frames(data, e["offset"] + e["length"])
    return data[pos:pos + 4] == CHUNK_MAGIC


def _rescue_unclaimed(data: bytes, start: int, by_offset: dict,
                      header: dict) -> list[dict]:
    """v3 gap walk: records whose commit AND footer entry are both gone
    can still be claimed when their envelope parses and every content
    frame passes its CRC — the metadata the commit would have carried
    (line range, dictionary bases) is re-derived by decoding along the
    chain. Stops at the first record that fails either test."""
    from .codec import _deserialize_template, decompress

    templates = [tuple(t) for t in header.get("seed_templates", [])]
    params = list(header.get("seed_params", []))
    rescued: list[dict] = []
    pos, line = start, 0
    while pos < len(data):
        e = by_offset.get(pos)
        if e is not None:
            # claimed record: trust its metadata, apply its delta frames
            # (pad on damage) so later unclaimed chunks keep decoding
            line = e["line_start"] + e["n_lines"]
            try:
                bl, tl, pl = e["g"] if e.get("g") else _parse_frame_lengths(
                    data, e["offset"])
                (_, _), (to, tl_), (po, pl_), _ = frame_positions(bl, tl, pl)
                td = data[e["offset"] + to:e["offset"] + to + tl_]
                pd = data[e["offset"] + po:e["offset"] + po + pl_]
                integrity.verify(td, data[e["offset"] + to + tl_:
                                          e["offset"] + to + tl_ + CRC_LEN],
                                 frame="template_delta")
                integrity.verify(pd, data[e["offset"] + po + pl_:
                                          e["offset"] + po + pl_ + CRC_LEN],
                                 frame="paramdict_delta")
                templates.extend(tuple(_deserialize_template(s))
                                 for s in split_column(zlib.decompress(td)))
                params.extend(split_column(zlib.decompress(pd)))
            except Exception:
                templates.extend([None] * e["n_delta"])
                params.extend([None] * e.get("pd_delta", 0))
            pos = skip_opt_frames(data, e["offset"] + e["length"])
            continue
        if data[pos:pos + 4] != CHUNK_MAGIC:
            break
        try:
            off = pos
            bl, p = _take_varint(data, pos + 4)
            blob = data[p:p + bl]
            if len(blob) != bl:
                break
            integrity.verify(blob, bytes(data[p + bl:p + bl + CRC_LEN]),
                             frame="chunk_payload", offset=p, chunk=-1)
            doffset = p + bl + CRC_LEN
            tl, p = _take_varint(data, doffset)
            td = data[p:p + tl]
            integrity.verify(td, bytes(data[p + tl:p + tl + CRC_LEN]),
                             frame="template_delta", offset=p, chunk=-1)
            p += tl + CRC_LEN
            pl, p = _take_varint(data, p)
            pd = data[p:p + pl]
            if len(td) != tl or len(pd) != pl:
                break
            integrity.verify(pd, bytes(data[p + pl:p + pl + CRC_LEN]),
                             frame="paramdict_delta", offset=p, chunk=-1)
            commit_at = p + pl + CRC_LEN
            tpl_base, pd_base = len(templates), len(params)
            new_t = [tuple(_deserialize_template(s))
                     for s in split_column(zlib.decompress(td))]
            new_p = split_column(zlib.decompress(pd))
            templates.extend(new_t)
            params.extend(new_p)
            lines = decompress(blob, ext_templates=templates, ext_params=params)
        except Exception:
            break
        # the commit's byte length is fully determined by the re-derived
        # values, so the record end is known even with the commit damaged
        end = commit_at + len(build_commit(
            off, bl, tl, pl, line, len(lines), tpl_base, len(new_t),
            pd_base, len(new_p)))
        if end > len(data):
            break  # commit region never landed: the record was not committed
        rescued.append({
            "offset": off, "length": end - off, "doffset": doffset,
            "line_start": line, "n_lines": len(lines),
            "tpl_base": tpl_base, "n_delta": len(new_t),
            "pd_base": pd_base, "pd_delta": len(new_p),
            "match_rate": 0.0, "manifest": None,
        })
        line += len(lines)
        # a rescued record's commit-derived end excludes any optional
        # screen frame the writer appended after the commit
        pos = skip_opt_frames(data, end)
    return rescued


def _parse_frame_lengths(data: bytes, off: int) -> tuple[int, int, int]:
    """(blob_len, td_len, pd_len) of the v3 record at ``off``, from its
    envelope varints; raises on structural damage."""
    if data[off:off + 4] != CHUNK_MAGIC:
        raise ValueError("bad magic")
    bl, p = _take_varint(data, off + 4)
    tl, p = _take_varint(data, p + bl + CRC_LEN)
    pl, _ = _take_varint(data, p + tl + CRC_LEN)
    return bl, tl, pl


def _expected_envelope(e: dict, bl: int, tl: int, pl: int):
    """The canonical envelope byte runs for a chunk record with the given
    frame lengths: (relative_offset, bytes) for the CHNK magic + blob
    varint, the two delta-length varints and the sealed commit. Every one
    of these is derivable from trusted metadata alone."""
    (bo, _), (to, _), (_po, _), cpos = frame_positions(bl, tl, pl)
    return (
        (0, CHUNK_MAGIC + _varint_bytes(bl)),
        (bo + bl + CRC_LEN, _varint_bytes(tl)),
        (to + tl + CRC_LEN, _varint_bytes(pl)),
        (cpos, build_commit(e["offset"], bl, tl, pl, e["line_start"],
                            e["n_lines"], e["tpl_base"], e["n_delta"],
                            e["pd_base"], e.get("pd_delta", 0))),
    )


def _verify_entry(data: bytes, k: int, e: dict, version: int) -> dict:
    """Frame-verify one chunk record in ``data`` -> {frame: error}."""
    rec = data[e["offset"]:e["offset"] + e["length"]]
    if len(rec) != e["length"]:
        return {"record": f"short record ({len(rec)}/{e['length']} bytes)"}
    try:
        parsed = parse_chunk_record(rec, k, e["offset"], version >= V3,
                                    geometry=e.get("g"))
    except ValueError as err:
        return {"record": str(err)}
    bad = {f: str(err) for f, err in parsed["bad"].items()}
    g = e.get("g")
    if g is not None and version >= V3:
        # geometry came from the commit, so the frame slicing above never
        # touched the envelope bytes — compare them to the canonical form
        # so flips there are surfaced (repair heals them losslessly)
        for rel, exp in _expected_envelope(e, *g):
            got = rec[rel:rel + len(exp)]
            if got != exp:
                bad.setdefault(
                    "envelope",
                    f"record envelope mismatch at byte {e['offset'] + rel}")
    return bad


# --------------------------------------------------------------- salvage

def salvage_scan(f) -> dict:
    """Best-effort index reconstruction over an open binary file — the
    engine behind ``LZJSReader(salvage=True)``, ``fsck`` and ``repair``.

    Merges two evidence sources, either of which survives any single
    fault alone: the footer (when it still verifies) and the per-chunk
    sealed commits. Every merged entry is then frame-verified; content
    damage becomes a ``"q"`` quarantine mark (the reader skips those),
    envelope damage on commit-backed entries is tolerated via the
    ``"g"`` geometry key (and healed by ``repair``)."""
    f.seek(0)
    data = f.read()
    version, header, header_end, header_ok = _parse_header(data)
    footer, footer_ok = None, False
    try:
        footer, _ = _parse_footer(data, version)
        footer_ok = True
    except ValueError:
        pass

    by_offset: dict[int, dict] = {}
    if footer_ok:
        for e in footer.get("chunks", []):
            by_offset[e["offset"]] = dict(e)
    if version >= V3:
        for e in scan_commits(data):
            cur = by_offset.get(e["offset"])
            if cur is None:
                by_offset[e["offset"]] = e
            else:
                # commit-verified geometry rides along with the footer
                # entry: frames stay readable even when the record's own
                # envelope bytes took the hit
                cur["g"] = e["g"]
    elif not footer_ok:
        for e in _scan_sequential(data, header_end, header):
            by_offset[e["offset"]] = e
    index = [by_offset[o] for o in sorted(by_offset)]
    if version >= V3 and _has_unclaimed(data, header_end, index):
        for e in _rescue_unclaimed(data, header_end, by_offset, header):
            by_offset[e["offset"]] = e
        index = [by_offset[o] for o in sorted(by_offset)]

    statuses = []
    for k, e in enumerate(index):
        bad = _verify_entry(data, k, e, version)
        # quarantine only on CONTENT damage: a broken commit alongside a
        # verified footer entry (or vice versa) still reads fine — that
        # is exactly the single-fault redundancy the format is built on
        content_bad = {fr: m for fr, m in bad.items()
                       if fr in _FRAMES or fr == "record"}
        if content_bad and not e.get("q"):
            e["q"] = "; ".join(f"{fr}: {m}" for fr, m in sorted(content_bad.items()))
        statuses.append(sorted(bad) if bad else "ok")

    n_lines = footer["n_lines"] if footer_ok else \
        max((e["line_start"] + e["n_lines"] for e in index), default=0)
    data_end = max((e["offset"] + e["length"] for e in index), default=header_end)
    lost = []
    expect = 0
    for e in index:
        if e["line_start"] > expect:
            lost.append([expect, e["line_start"]])
        if e.get("q"):
            lost.append([e["line_start"], e["line_start"] + e["n_lines"]])
        expect = max(expect, e["line_start"] + e["n_lines"])
    if n_lines > expect:
        lost.append([expect, n_lines])

    if footer is None:
        footer = {"v": version, "n_lines": n_lines,
                  "level": header.get("level"), "kernel": header.get("kernel"),
                  "format": header.get("format"), "chunks": index}
        if version >= V3 and "typed" in header:
            footer["typed"] = header["typed"]
    else:
        footer = dict(footer)
        footer["chunks"] = index
        footer["n_lines"] = n_lines
    report = {
        "version": version, "header_ok": header_ok, "footer_ok": footer_ok,
        "n_chunks": len(index), "n_lines": n_lines,
        "chunk_status": statuses,
        "quarantined": [k for k, e in enumerate(index) if e.get("q")],
        "lost_line_ranges": lost,
    }
    return {"version": version, "header": header, "footer": footer,
            "index": index, "n_lines": n_lines, "data_end": data_end,
            "report": report}


# ------------------------------------------------------------ fsck/repair

def _finish_report(report: dict) -> dict:
    report["clean"] = (report["footer_ok"] and report["header_ok"]
                       and all(s == "ok" for s in report["chunk_status"])
                       and not report["quarantined"]
                       and not report["lost_line_ranges"])
    report["repairable"] = not report["clean"]
    return report


def fsck(src) -> dict:
    """Read-only diagnosis of an LZJS container. Returns the salvage
    report plus ``clean`` (nothing wrong) and ``repairable``."""
    own = isinstance(src, (str, os.PathLike))
    f = open(src, "rb") if own else src
    try:
        res = salvage_scan(f)
    finally:
        if own:
            f.close()
    return _finish_report(dict(res["report"]))


def _restore_envelopes(f, data: bytes, index: list[dict]) -> int:
    """Heal damaged record envelopes in place (v3): the CHNK magic,
    length varints and commit bytes are all derivable from trusted
    metadata (commit geometry, or a verified footer entry plus the
    record's parsed frames), so flips there are rewritten instead of
    costing the chunk. Returns the number of records patched."""
    patched = 0
    for k, e in enumerate(index):
        off = e["offset"]
        if e.get("g"):
            bl, tl, pl = e["g"]
        else:
            # footer-backed entry, commit possibly damaged: recover the
            # frame lengths from the (intact) envelope parse
            try:
                parsed = parse_chunk_record(
                    data[off:off + e["length"]], k, off, True)
            except ValueError:
                continue  # content damage — quarantine handles it
            bl, tl, pl = (len(parsed["blob"]), len(parsed["td"]),
                          len(parsed["pd"]))
        dirty = False
        for rel, exp in _expected_envelope(e, bl, tl, pl):
            if data[off + rel:off + rel + len(exp)] != exp:
                f.seek(off + rel)
                f.write(exp)
                dirty = True
        if dirty:
            patched += 1
        e.pop("g", None)  # envelope now canonical: stored bytes trustworthy
    return patched


def repair(path) -> dict:
    """Repair an LZJS container in place: restore record envelopes,
    quarantine content-damaged chunks, test-decode every survivor and
    rewrite a verified footer after the last committed record. A clean
    container is left untouched. Returns the fsck-style report extended
    with the actions taken."""
    with open(path, "r+b") as f:
        res = salvage_scan(f)
        version, index = res["version"], res["index"]
        report = _finish_report(dict(res["report"]))
        if report["clean"]:
            report["envelopes_restored"] = 0
            report["decode_failed"] = []
            return report
        patched = 0
        if version >= V3:
            f.seek(0)
            patched = _restore_envelopes(f, f.read(), index)
            f.flush()

        # footer metadata: prefer the old footer, then the header, then
        # the first readable chunk's own framing
        footer = res["footer"]
        if not footer.get("kernel") or not footer.get("level"):
            for k, e in enumerate(index):
                if e.get("q"):
                    continue
                f.seek(e["offset"])
                rec = f.read(e["length"])
                try:
                    blob = parse_chunk_record(rec, k, e["offset"],
                                              version >= V3)["blob"]
                except ValueError:
                    continue
                footer["kernel"] = footer.get("kernel") or KERNEL_BY_ID.get(blob[4])
                footer["level"] = footer.get("level") or (blob[5] & 0x7F)
                break

    # test-decode on the healed bytes: chunks whose frames verify can
    # still be undecodable when they dereference templates/params lost
    # with an earlier quarantined chunk — find those now, not at some
    # future read
    probe = LZJSReader(path, salvage=True)
    decode_failed = []
    try:
        by_off = {e["offset"]: e for e in index}
        for k in range(len(probe)):
            pe = probe.index[k]
            e = by_off.get(pe["offset"])
            if e is None:
                continue
            if pe.get("q"):
                e["q"] = e.get("q") or pe["q"]
                continue
            if probe._chunk_lines_or_skip(k) is None:
                e["q"] = probe.index[k]["q"]
                decode_failed.append(k)
    finally:
        probe.close()

    with open(path, "r+b") as f:
        for e in index:
            e.pop("g", None)
        footer["chunks"] = index
        fb = zlib.compress(json.dumps(footer).encode("utf-8"))
        f.seek(res["data_end"])
        f.write(fb)
        if version >= V3:
            f.write(integrity.trailer(fb))
        f.write(len(fb).to_bytes(8, "little"))
        f.write(FOOTER_MAGIC)
        f.truncate()
        f.flush()
        try:
            os.fsync(f.fileno())
        except OSError:
            pass

    report["quarantined"] = [k for k, e in enumerate(index) if e.get("q")]
    report["envelopes_restored"] = patched
    report["decode_failed"] = decode_failed
    lost = []
    expect = 0
    for e in index:
        if e["line_start"] > expect:
            lost.append([expect, e["line_start"]])
        if e.get("q"):
            lost.append([e["line_start"], e["line_start"] + e["n_lines"]])
        expect = max(expect, e["line_start"] + e["n_lines"])
    if footer["n_lines"] > expect:
        lost.append([expect, footer["n_lines"]])
    report["lost_line_ranges"] = lost
    return report


def ensure_clean(path) -> dict:
    """fsck; repair only when needed. The ingestion daemon's tenant
    bootstrap (DESIGN.md §15): every (re)open runs this first, so a
    session killed mid-write is healed before WAL replay resumes it.
    Returns the report, extended with ``n_lines`` — the durable line
    count the WAL replay starts from."""
    report = fsck(path)
    if not report["clean"]:
        report = repair(path)
    rd = LZJSReader(path)
    try:
        report["n_lines"] = rd.n_lines
    finally:
        rd.close()
    return report
