"""Roofline report over artifacts/dryrun/*.json (deliverable g).

Per (arch x shape x mesh): the three roofline terms in seconds, the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs usefulness ratio, per-device
memory, and a one-line "what would move the dominant term" note.

Run:  PYTHONPATH=src python -m benchmarks.roofline [--dir artifacts/dryrun]
Emits markdown to stdout (EXPERIMENTS.md embeds the output).
"""

from __future__ import annotations

import argparse
import glob
import json
import os

V5E_NOTE = "TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI"

MOVE_NOTES = {
    "compute": "raise arithmetic efficiency: bigger microbatch / less remat recompute",
    "memory": "cut boundary traffic: bf16 flash carries, larger ssm/attn chunks, fuse norms",
    "collective": "cut wire bytes: bf16 psums, 2D-shard logits collectives, overlap FSDP gathers",
}


def load(dirname: str) -> list[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        if f.endswith("sweep_summary.json"):
            continue
        with open(f) as fh:
            rows.append(json.load(fh))
    return rows


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:7.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:6.1f}ms"
    return f"{x*1e6:6.1f}us"


def report(rows: list[dict], mesh: str = "single") -> str:
    out = [f"### Roofline — {mesh} pod ({'256' if mesh == 'single' else '512'} chips; {V5E_NOTE})", ""]
    out.append("| arch | shape | t_compute | t_memory (tpu-adj) | t_collective | bound | useful-FLOPs | temp GB/dev | note |")
    out.append("|---|---|---|---|---|---|---|---|---|")
    sel = [r for r in rows if r["mesh"] == mesh]
    sel.sort(key=lambda r: (r["arch"], r["shape"]))
    for r in sel:
        t = r["roofline"]
        note = MOVE_NOTES[t["dominant"]]
        mem = fmt_s(t["t_memory_s"])
        if "t_memory_tpu_s" in t:
            mem += f" ({fmt_s(t['t_memory_tpu_s'])})"
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(t['t_compute_s'])} | {mem} "
            f"| {fmt_s(t['t_collective_s'])} | **{t['dominant']}** | {r['useful_flops_ratio']:.3f} "
            f"| {r['memory']['temp_bytes']/1e9:.1f} | {note} |"
        )
    return "\n".join(out)


def pick_hillclimb(rows: list[dict]) -> dict:
    """worst roofline fraction / most collective-bound / paper-representative."""
    single = [r for r in rows if r["mesh"] == "single"]

    def frac(r):  # compute share of the bound = roofline fraction proxy
        t = r["roofline"]
        lb = max(t["step_time_lower_bound_s"], 1e-12)
        return t["t_compute_s"] / lb

    worst = min(single, key=frac)
    coll = max(single, key=lambda r: r["roofline"]["t_collective_s"] /
               max(r["roofline"]["step_time_lower_bound_s"], 1e-12))
    return {"worst_fraction": worst, "most_collective": coll}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun"))
    args = ap.parse_args()
    rows = load(args.dir)
    if not rows:
        print("no artifacts found — run scripts/sweep_dryrun.py first")
        return
    print(report(rows, "single"))
    print()
    print(report(rows, "multi"))
    picks = pick_hillclimb(rows)
    print("\n### Hillclimb picks")
    for k, r in picks.items():
        print(f"- {k}: {r['arch']} x {r['shape']} (dominant={r['roofline']['dominant']})")


if __name__ == "__main__":
    main()
