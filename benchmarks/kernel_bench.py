"""Throughput of the logzip hot-spot kernels (interpret mode on CPU — the
numbers calibrate RELATIVE costs; absolute TPU throughput needs hardware).

Compares: python trie, numpy DP matcher, Pallas wildcard_match
(interpret), and numpy vs Pallas simcount, on a realistic template mix.
"""

from __future__ import annotations

import time


from repro.core.match import match_first
from repro.core.tokenizer import Vocab, tokenize
from repro.core.trie import PrefixTree
from repro.data.loggen import generate_lines
from repro.kernels import ops


def _prep(n_lines=20000):
    v = Vocab()
    lines = generate_lines("Spark", n_lines, seed=3)
    toks = [tokenize(l.split(": ", 1)[-1])[0] for l in lines]
    ids, lens = v.encode_batch(toks, 48)
    # build templates from a sample via ISE
    from repro.core.ise import ISEConfig, iterative_structure_extraction

    res = iterative_structure_extraction(ids[:4000], lens[:4000], vocab_size=len(v),
                                         cfg=ISEConfig(min_sample=300))
    return ids, lens, res.templates


def run(n_lines=20000) -> list[dict]:
    ids, lens, templates = _prep(n_lines)
    rows = []

    t0 = time.time()
    tree = PrefixTree()
    for i, t in enumerate(templates):
        tree.insert(t, i)
    a_trie, _ = tree.match_batch(ids, lens)
    rows.append({"impl": "trie (python)", "lines_per_s": len(ids) / (time.time() - t0)})

    t0 = time.time()
    a_np = match_first(ids, lens, templates, use_kernel=False)
    rows.append({"impl": "DP matcher (numpy)", "lines_per_s": len(ids) / (time.time() - t0)})

    t0 = time.time()
    a_k = match_first(ids, lens, templates, use_kernel=True)
    rows.append({"impl": "wildcard_match (pallas interpret)", "lines_per_s": len(ids) / (time.time() - t0)})

    assert ((a_np >= 0) == (a_trie >= 0)).all()
    assert (a_np == a_k).all()

    tm, tl = ops.pack_templates(templates)
    t0 = time.time()
    ops.simcount(ids[:8192], tm).block_until_ready()
    rows.append({"impl": "simcount (pallas interpret)", "lines_per_s": 8192 / (time.time() - t0)})
    rows.extend(run_fused_kernels(n_lines))
    return rows


def run_fused_kernels(n_lines=20000) -> list[dict]:
    """Microbenchmarks for the ISSUE 3 device kernels vs their host
    references: the byte tokenizer/hasher and the fused match+extract
    pass, reported as bytes/sec over the raw input they consume."""
    import jax.numpy as jnp

    from repro.core.tokenizer import Vocab, tokenize_batch
    from repro.kernels.tokenize import hash_powers, tokenize_hash

    lines = [l.split(": ", 1)[-1] for l in generate_lines("Spark", n_lines, seed=3)]
    raw_bytes = sum(len(l.encode("utf-8", "surrogateescape")) for l in lines)
    rows: list[dict] = []

    # --- tokenizer: host vectorized grid vs device kernel
    t0 = time.time()
    tokenize_batch(lines, Vocab(), 48)
    host_s = time.time() - t0
    rows.append({"impl": "tokenize_batch (host numpy)",
                 "bytes_per_s": raw_bytes / host_s, "lines_per_s": n_lines / host_s})

    blocks, blens, _ = ops.pack_lines(lines)
    pws = hash_powers(blocks.shape[1])
    delims = tuple(ord(c) for c in " \t,;:=")
    args = (jnp.asarray(blocks), jnp.asarray(blens),
            jnp.asarray(pws[0][0]), jnp.asarray(pws[1][0]))
    tokenize_hash(*args, delims=delims)  # warm the jit cache
    t0 = time.time()
    out = tokenize_hash(*args, delims=delims)
    out[0].block_until_ready()
    dev_s = time.time() - t0
    rows.append({"impl": "tokenize_hash (pallas interpret)",
                 "bytes_per_s": raw_bytes / dev_s, "lines_per_s": n_lines / dev_s})

    # --- fused match+extract: host anchor pass vs device kernel
    v = Vocab()
    grid = tokenize_batch(lines, v, 48)
    from repro.core.ise import ISEConfig, iterative_structure_extraction
    from repro.core.match import extract_spans, match_first

    res = iterative_structure_extraction(grid.ids[:4000], grid.lens[:4000],
                                         vocab_size=len(v),
                                         cfg=ISEConfig(min_sample=300))
    t0 = time.time()
    a = match_first(grid.ids, grid.lens, res.templates, use_kernel=False)
    for g in sorted(set(a[a >= 0].tolist())):
        rws = (a == g).nonzero()[0]
        extract_spans(grid.ids[rws], grid.lens[rws], res.templates[g])
    host_s = time.time() - t0
    rows.append({"impl": "match+extract (host fused anchors)",
                 "bytes_per_s": raw_bytes / host_s, "lines_per_s": n_lines / host_s})

    sub = min(n_lines, 4096)  # interpret mode: keep the device pass bounded
    # warm at the SAME shape bucket as the timed call, or the timing
    # window would include a full re-trace
    ops.match_extract(grid.ids[:sub], grid.lens[:sub], res.templates)
    t0 = time.time()
    ops.match_extract(grid.ids[:sub], grid.lens[:sub], res.templates)
    dev_s = time.time() - t0
    frac = sub / n_lines
    rows.append({"impl": "match_extract (pallas interpret)",
                 "bytes_per_s": raw_bytes * frac / dev_s, "lines_per_s": sub / dev_s})
    return rows
