"""Throughput of the logzip hot-spot kernels (interpret mode on CPU — the
numbers calibrate RELATIVE costs; absolute TPU throughput needs hardware).

Compares: python trie, numpy DP matcher, Pallas wildcard_match
(interpret), and numpy vs Pallas simcount, on a realistic template mix.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.match import match_first
from repro.core.tokenizer import Vocab, tokenize
from repro.core.trie import PrefixTree
from repro.data.loggen import generate_lines
from repro.kernels import ops


def _prep(n_lines=20000):
    v = Vocab()
    lines = generate_lines("Spark", n_lines, seed=3)
    toks = [tokenize(l.split(": ", 1)[-1])[0] for l in lines]
    ids, lens = v.encode_batch(toks, 48)
    # build templates from a sample via ISE
    from repro.core.ise import ISEConfig, iterative_structure_extraction

    res = iterative_structure_extraction(ids[:4000], lens[:4000], vocab_size=len(v),
                                         cfg=ISEConfig(min_sample=300))
    return ids, lens, res.templates


def run(n_lines=20000) -> list[dict]:
    ids, lens, templates = _prep(n_lines)
    rows = []

    t0 = time.time()
    tree = PrefixTree()
    for i, t in enumerate(templates):
        tree.insert(t, i)
    a_trie, _ = tree.match_batch(ids, lens)
    rows.append({"impl": "trie (python)", "lines_per_s": len(ids) / (time.time() - t0)})

    t0 = time.time()
    a_np = match_first(ids, lens, templates, use_kernel=False)
    rows.append({"impl": "DP matcher (numpy)", "lines_per_s": len(ids) / (time.time() - t0)})

    t0 = time.time()
    a_k = match_first(ids, lens, templates, use_kernel=True)
    rows.append({"impl": "wildcard_match (pallas interpret)", "lines_per_s": len(ids) / (time.time() - t0)})

    assert ((a_np >= 0) == (a_trie >= 0)).all()
    assert (a_np == a_k).all()

    tm, tl = ops.pack_templates(templates)
    t0 = time.time()
    ops.simcount(ids[:8192], tm).block_until_ready()
    rows.append({"impl": "simcount (pallas interpret)", "lines_per_s": 8192 / (time.time() - t0)})
    return rows
