"""Paper-table benchmarks (Table II, Fig 6, Fig 7, §V-D match-rate).

Synthetic corpora stand in for loghub (offline container, DESIGN.md §6.4):
absolute CRs differ from the paper; the validation targets are the
ORDERINGS and ablation shapes. Sizes are scaled down (default ~8 MB per
dataset) to finish on one CPU core; pass --lines to scale up.
"""

from __future__ import annotations

import time

from repro.core.baselines import cowic_like, kernel_baseline, logarchive_like
from repro.core.codec import LogzipConfig, compress, read_structured
from repro.core.ise import ISEConfig
from repro.core.parallel import compress_parallel
from repro.data.loggen import DATASETS, generate_lines

ISE_FAST = ISEConfig(sample_rate=0.01, min_sample=400, max_iters=4)


def _corpus(name: str, n_lines: int, seed: int = 0):
    lines = list(generate_lines(name, n_lines, seed))
    raw = sum(len(l.encode()) + 1 for l in lines) - 1
    return lines, raw


def table2(n_lines: int = 40000) -> list[dict]:
    """Table II: CR of raw kernels, Cowic/LogArchive-like, logzip(level 3)."""
    rows = []
    for name in DATASETS:
        lines, raw = _corpus(name, n_lines)
        fmt = DATASETS[name]["format"]
        row = {"dataset": name, "raw_mb": raw / 1e6}
        for k in ("gzip", "bzip2", "lzma"):
            t0 = time.time()
            row[k] = raw / len(kernel_baseline(lines, k))
            row[f"{k}_s"] = time.time() - t0
        row["cowic_like"] = raw / len(cowic_like(lines))
        row["logarchive_like"] = raw / len(logarchive_like(lines))
        for k in ("gzip", "bzip2", "lzma"):
            t0 = time.time()
            blob = compress(lines, LogzipConfig(level=3, kernel=k, format=fmt, ise=ISE_FAST))
            row[f"logzip_{k}"] = raw / len(blob)
            row[f"logzip_{k}_s"] = time.time() - t0
        row["improvement_gzip"] = row["logzip_gzip"] / row["gzip"]
        rows.append(row)
    return rows


def fig6_levels(n_lines: int = 40000) -> list[dict]:
    """Fig 6: compressed size by logzip level (gzip kernel) vs raw gzip."""
    rows = []
    for name in DATASETS:
        lines, raw = _corpus(name, n_lines)
        fmt = DATASETS[name]["format"]
        row = {"dataset": name, "raw_mb": raw / 1e6,
               "gzip_mb": len(kernel_baseline(lines, "gzip")) / 1e6}
        for level in (1, 2, 3):
            blob = compress(lines, LogzipConfig(level=level, kernel="gzip", format=fmt, ise=ISE_FAST))
            row[f"L{level}_mb"] = len(blob) / 1e6
        rows.append(row)
    return rows


def fig7_workers(n_lines: int = 40000, workers=(1, 2, 4, 8)) -> list[dict]:
    """Fig 7: chunked multi-worker compression.

    NOTE: this container exposes ONE cpu core, so wall-time cannot show
    the paper's near-linear scaling; we report measured wall time, the
    per-chunk CPU-time sum, and ideal_time = cpu_time / workers (what a
    w-core host gets — the paper's result), plus the compressed-size
    growth from chunking, which IS measurable here and matches Fig 7.
    """
    rows = []
    for name in ("HDFS", "Spark"):
        lines, raw = _corpus(name, n_lines)
        cfg = LogzipConfig(level=3, kernel="gzip", format=DATASETS[name]["format"], ise=ISE_FAST)
        whole = len(compress(lines, cfg))
        for w in workers:
            chunk = max(1, (len(lines) + w - 1) // w)
            t0 = time.time()
            blob = compress_parallel(lines, cfg, n_workers=1, chunk_lines=chunk)  # serial = cpu time
            cpu_s = time.time() - t0
            rows.append({
                "dataset": name, "workers": w, "chunks": -(-len(lines) // chunk),
                "cpu_time_s": cpu_s, "ideal_wall_s": cpu_s / w,
                "size_mb": len(blob) / 1e6, "size_vs_whole": len(blob) / whole,
            })
    return rows


def match_rate(n_lines: int = 60000) -> list[dict]:
    """§V-D: ~1% sample yields >= 90% match in the first iterations."""
    rows = []
    for name in DATASETS:
        lines, raw = _corpus(name, n_lines)
        cfg = LogzipConfig(level=2, kernel="gzip", format=DATASETS[name]["format"],
                           ise=ISEConfig(sample_rate=0.01, min_sample=200, max_iters=4))
        blob = compress(lines, cfg)
        s = read_structured(blob)
        rows.append({"dataset": name, "match_rate": s["match_rate"],
                     "n_templates": len(s["templates"])})
    return rows
