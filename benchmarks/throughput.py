"""Compression throughput benchmark -> ``BENCH_compress.json``.

Measures ``compress()`` end-to-end (lines/sec, MB/s) with the per-stage
wall-time breakdown from ``codec.StageTimer`` (parse / dedup / tokenize /
encode / ise.cluster / ise.match / spans / columns / pack / kernel), on:

- the 40k-line synthetic HDFS corpus (level 3, gzip kernel) — the
  recorded perf trajectory every PR appends to;
- the same corpus with the dedup fast path disabled (ablation);
- a duplicate-heavy variant (each distinct line repeated ~10x, the
  regime real logs live in — LogShrink/LogLite's observation) where the
  dedup stage collapses most of the work;
- a streaming-session scenario (``bench_streaming``): single-archive vs
  per-chunk-independent vs shared-store ``StreamingCompressor`` CR (the
  session must close >= half the chunking CR gap), plus a footer-index
  random-access check (a 1k-line range decodes only covering chunks);
- a ``device_pipeline`` scenario (ISSUE 3): a 20-chunk streaming session
  through the Pallas kernel matcher with bucketed shapes, recording the
  per-bucket call counts and the recompile (re-trace) counter after
  warmup — the jit-cache contract is zero, and ``check_perf_gate.py``
  fails CI if it regresses. On CPU the kernels run in interpret mode, so
  this scenario's lines/sec calibrates *relative* cost only;
- a ``query`` scenario (ISSUE 4): compressed-domain grep over an LZJS
  session with a rare-template burst — selective literal/regex queries, a
  point param query and a field-equality query, each verified hit-for-hit
  against decompress-then-grep, reporting matched-lines/s, the fraction
  of chunks decoded and the speedup vs the baseline (gated by
  ``check_perf_gate.py``: selective queries must decode <50% of chunks
  and beat the baseline wall clock).

``SEED_REFERENCE`` is the seed-tree measurement of the same 40k-line
HDFS / level-3 / gzip configuration in this container, recorded when the
fast path landed; ``speedup_vs_seed`` in the JSON is computed against it.

PYTHONPATH=src python -m benchmarks.throughput [--quick] [--lines N] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import time

import numpy as np

from repro.core.codec import LogzipConfig, compress, decompress
from repro.core.ise import ISEConfig
from repro.data.loggen import generate_lines

ISE_FAST = ISEConfig(sample_rate=0.01, min_sample=400, max_iters=4)

# seed compress() on this exact benchmark (40k-line synthetic HDFS,
# level 3, gzip kernel), measured in this container at commit 9e78cd3
# before the dedup/vectorization fast path landed.
SEED_REFERENCE = {"lines_per_sec": 3050.0, "wall_s": 13.11, "commit": "9e78cd3"}


def _dup_heavy(name: str, n_lines: int, factor: int = 10, seed: int = 0) -> list[str]:
    """~n_lines lines with each distinct line repeated ``factor``x, shuffled
    deterministically — the exact-duplicate regime of production logs."""
    base = list(generate_lines(name, max(1, n_lines // factor), seed))
    lines = base * factor
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(lines))
    return [lines[i] for i in order]


def bench_one(lines: list[str], cfg: LogzipConfig, label: str, *, verify: bool = True,
              scenario: str | None = None) -> dict:
    raw_bytes = sum(len(l.encode("utf-8", "surrogateescape")) + 1 for l in lines) - 1
    stages: dict[str, float] = {}
    t0 = time.perf_counter()
    blob = compress(lines, cfg, stage_times=stages)
    wall = time.perf_counter() - t0
    if verify:
        assert decompress(blob) == lines, f"{label}: lossless round-trip FAILED"
    return {
        "label": label,
        "scenario": scenario,
        "n_lines": len(lines),
        "raw_mb": raw_bytes / 1e6,
        "level": cfg.level,
        "kernel": cfg.kernel,
        "dedup": cfg.dedup,
        "wall_s": round(wall, 4),
        "lines_per_sec": round(len(lines) / wall, 1),
        "mb_per_sec": round(raw_bytes / 1e6 / wall, 3),
        "compressed_bytes": len(blob),
        "compression_ratio": round(raw_bytes / len(blob), 3),
        "stages_s": {k: round(v, 4) for k, v in sorted(stages.items())},
    }


def bench_streaming(lines: list[str], cfg: LogzipConfig, cr_single: float,
                    chunk_lines: int) -> dict:
    """Streaming-session scenario (ISSUE 2 acceptance): shared-store
    chunked compression must close >= half the CR gap between
    per-chunk-independent and single-archive compression, within 10% of
    the chunked path's lines/sec; random access must decode only the
    chunks covering the requested range."""
    import dataclasses
    import io

    from repro.core.parallel import compress_parallel, decompress_parallel
    from repro.core.stream import LZJSReader, StreamingCompressor

    n = len(lines)
    raw_bytes = sum(len(l.encode("utf-8", "surrogateescape")) + 1 for l in lines) - 1

    t0 = time.perf_counter()
    chunked = compress_parallel(lines, cfg, n_workers=1, chunk_lines=chunk_lines)
    wall_chunked = time.perf_counter() - t0
    assert decompress_parallel(chunked) == lines, "chunked round-trip FAILED"

    # like-for-like CR: the chunked LZJM baseline has no screen frames,
    # so the gap-closure metric excludes them too (their size is measured
    # and <1%-gated in the query scenario, where they earn their keep)
    cfg = dataclasses.replace(cfg, screens=False)
    buf = io.BytesIO()
    t0 = time.perf_counter()
    with StreamingCompressor(buf, cfg, chunk_lines=chunk_lines) as sc:
        sc.feed(lines)
        summary = sc.close()
    wall_stream = time.perf_counter() - t0
    blob = buf.getvalue()

    rd = LZJSReader(io.BytesIO(blob))
    assert rd.read_all() == lines, "streaming round-trip FAILED"

    # random access: a 1k-line range must only decode covering chunks
    # (start clamped so tiny --lines runs still verify a non-empty range)
    start = min(n // 2 + 137, max(n - 1, 0))
    count = min(1000, n - start)
    rd2 = LZJSReader(io.BytesIO(blob))
    got = rd2.read_range(start, count)
    covering = rd2.covering_chunks(start, count)
    ra_ok = (count > 0 and got == lines[start:start + count]
             and rd2.chunks_decoded == len(covering))

    cr_chunked = raw_bytes / len(chunked)
    cr_stream = raw_bytes / len(blob)
    gap = cr_single - cr_chunked
    return {
        "chunk_lines": chunk_lines,
        "n_chunks": summary["n_chunks"],
        "n_templates": summary["n_templates"],
        "cr_single": round(cr_single, 3),
        "cr_chunked": round(cr_chunked, 3),
        "cr_streaming": round(cr_stream, 3),
        "cr_gap_closed": round((cr_stream - cr_chunked) / gap, 3) if gap > 0 else 1.0,
        "chunked_lines_per_sec": round(n / wall_chunked, 1),
        "streaming_lines_per_sec": round(n / wall_stream, 1),
        "throughput_vs_chunked": round(wall_chunked / wall_stream, 3),
        "random_access": {
            "start": start, "count": count,
            "chunks_total": len(rd2), "chunks_covering": len(covering),
            "chunks_decoded": rd2.chunks_decoded, "ok": bool(ra_ok),
        },
    }


def bench_query(lines: list[str], cfg: LogzipConfig, chunk_lines: int) -> dict:
    """Compressed-domain query scenario (ISSUE 4 + ISSUE 7 acceptance):
    hit sets must be byte-identical to decompress-then-grep; the
    selective query must decode <50% of LZJS chunks and beat the
    baseline wall clock; with chunk screens, the point query must open
    O(1) chunks and the aggregations must beat decompress-then-compute
    with zero rows materialized.

    The corpus gets a localized rare-template burst (a "deployment
    event": lines that exist only in a narrow region of the stream) —
    the paper's own motivation for archiving logs is tracing exactly such
    recurrent problems / security incidents later."""
    import io
    import re as _re
    from collections import Counter

    from repro.core import query as Q
    from repro.core.parallel import decompress_parallel
    from repro.core.stream import StreamingCompressor
    from repro.core.tokenizer import LogFormat

    n0 = len(lines)
    at = (n0 * 7) // 10
    burst = [
        f"081109 203545 99 INFO dfs.FSNamesystem: Starting decommission of "
        f"node /10.9.{i % 7}.{i % 11} remaining {i}"
        for i in range(max(60, n0 // 400))
    ]
    lines = lines[:at] + burst + lines[at:]

    buf = io.BytesIO()
    with StreamingCompressor(buf, cfg, chunk_lines=chunk_lines) as sc:
        sc.feed(lines)
    blob = buf.getvalue()

    t0 = time.perf_counter()
    decoded = decompress_parallel(blob)
    t_decompress = time.perf_counter() - t0
    assert decoded == lines, "query benchmark: decode mismatch"

    # a parameter value occurring on as few lines as possible (point query)
    blk_counts = Counter(t for l in lines for t in l.split() if t.startswith("blk_"))
    min_count = min(blk_counts.values())
    rare_blk = min(t for t, c in blk_counts.items() if c == min_count)

    fmt = LogFormat(cfg.format)
    cols, ok_idx, _ = fmt.parse(lines)

    def base_field_eq(field, value):
        return [(i, lines[i]) for r, i in enumerate(ok_idx)
                if cols[field][r] == value]

    # field_eq targets the burst timestamp: Time is monotone, so the
    # manifest field-bound screens confine it to the burst chunks plus
    # the one organic region sharing the value (the ISSUE 7 gate).
    # field_eq_hot (Level=WARN) is everywhere by construction —
    # unprunable, kept as an agreement/throughput row only.
    queries = [
        ("selective_literal", Q.Substring("decommission"),
         lambda: [(i, l) for i, l in enumerate(lines) if "decommission" in l]),
        ("selective_regex", Q.Regex(r"decommission of node /10\.9\.\d+"),
         lambda: [(i, l) for i, l in enumerate(lines)
                  if _re.search(r"decommission of node /10\.9\.\d+", l)]),
        ("param_value", Q.Substring(rare_blk),
         lambda: [(i, l) for i, l in enumerate(lines) if rare_blk in l]),
        ("field_eq", Q.FieldEq("Time", "203545"),
         lambda: base_field_eq("Time", "203545")),
        ("field_eq_hot", Q.FieldEq("Level", "WARN"),
         lambda: base_field_eq("Level", "WARN")),
    ]
    rows = []
    for name, q, base_fn in queries:
        st = Q.QueryStats()
        t0 = time.perf_counter()
        hits = list(Q.search(blob, q, stats=st))
        wall = time.perf_counter() - t0
        t0 = time.perf_counter()
        base_hits = base_fn()
        t_scan = time.perf_counter() - t0
        base_wall = t_decompress + t_scan
        rows.append({
            "query": name,
            "hits": len(hits),
            "hits_agree": hits == base_hits,
            "wall_s": round(wall, 4),
            "matched_lines_per_sec": round(len(hits) / wall, 1) if wall else None,
            "chunks_opened": st.chunks_opened,
            "chunks_total": st.chunks_total,
            "fraction_chunks_decoded": round(st.fraction_chunks_decoded, 4),
            "rows_materialized": st.rows_materialized,
            "chunks_skipped_by": dict(st.chunks_skipped_by),
            "bloom_probes": st.bloom_probes,
            "bloom_passes": st.bloom_passes,
            "bloom_false_positives": st.bloom_false_positives,
            "baseline_wall_s": round(base_wall, 4),
            "speedup_vs_baseline": round(base_wall / wall, 2) if wall else None,
        })

    st = Q.QueryStats()
    t0 = time.perf_counter()
    n_term = Q.count(blob, Q.Substring("terminating"), stats=st)
    count_wall = time.perf_counter() - t0
    assert n_term == sum(1 for l in lines if "terminating" in l)

    # aggregations (ISSUE 7): answers must agree with decompress-then-
    # compute while never materializing a row of text
    from collections import Counter as _Counter
    aggs = []

    def agg_row(name, run_fn, base_fn):
        stq = Q.QueryStats()
        t0 = time.perf_counter()
        got = run_fn(stq)
        wall = time.perf_counter() - t0
        t0 = time.perf_counter()
        want = base_fn(decoded)
        t_compute = time.perf_counter() - t0
        base_wall = t_decompress + t_compute
        aggs.append({
            "agg": name,
            "agree": got == want,
            "wall_s": round(wall, 4),
            "rows_materialized": stq.rows_materialized,
            "chunks_opened": stq.chunks_opened,
            "chunks_counted_from_manifest": stq.chunks_counted_from_manifest,
            "baseline_wall_s": round(base_wall, 4),
            "speedup_vs_baseline": round(base_wall / wall, 2) if wall else None,
        })

    ev_truth = _Counter(r["event"] for r in Q.extract_records(blob))
    agg_row("count_by_template",
            lambda stq: Q.count_by_template(blob, stats=stq),
            lambda ls: dict(ev_truth))
    agg_row("top_k_level",
            lambda stq: Q.top_k(blob, "Level", k=5, stats=stq),
            lambda ls: sorted(
                _Counter(cols["Level"][r] for r in range(len(ok_idx))).items(),
                key=lambda kv: (-kv[1], kv[0]))[:5])
    agg_row("time_histogram",
            lambda stq: Q.time_histogram(blob, "Time", bucket=60, stats=stq),
            lambda ls: dict(sorted(_Counter(
                int(cols["Time"][r]) // 60 for r in range(len(ok_idx))).items())))

    # screen frame overhead, CR-gated at < 1% of the archive
    from repro.core.stream import LZJSReader
    rd = LZJSReader(io.BytesIO(blob))
    screen_bytes = sum(e["sc"][1] for e in rd.index if "sc" in e)
    rd.close()

    return {
        "n_lines": len(lines),
        "chunk_lines": chunk_lines,
        "baseline_decompress_s": round(t_decompress, 4),
        "screen_bytes": screen_bytes,
        "screen_bytes_fraction": round(screen_bytes / len(blob), 5),
        "queries": rows,
        "aggregations": aggs,
        "count_fast_path": {
            "query": "count(terminating)", "hits": n_term,
            "wall_s": round(count_wall, 4),
            "rows_materialized": st.rows_materialized,
            "chunks_opened": st.chunks_opened,
            "chunks_counted_from_manifest": st.chunks_counted_from_manifest,
        },
    }


# the per-dataset CR table always runs at this size, in BOTH quick and
# full runs: the CI gate compares fresh-vs-committed per-dataset CR at a
# 2% tolerance, which is only meaningful like-for-like (CR grows with
# corpus size, so a quick-vs-40k comparison would need sloppy slack)
DATASET_CR_LINES = 8000


def bench_datasets(n_lines: int = DATASET_CR_LINES) -> dict:
    """Per-dataset CR: typed columns (v2) vs the v1 text layout vs the
    checksummed v3 framing on every synthetic corpus (ISSUES 5/6).
    ``check_cr_gate.py`` fails CI if any dataset's typed CR regresses >2%
    vs the committed baseline, stops beating its own v1 baseline, or the
    v3 integrity overhead exceeds 0.5% of CR."""
    from repro.data.loggen import DATASETS

    variants = {"v3": (True, True), "typed": (True, False), "v1": (False, False)}
    rows = []
    for name, spec in DATASETS.items():
        lines = list(generate_lines(name, n_lines, seed=0))
        raw = sum(len(l.encode("utf-8", "surrogateescape")) + 1 for l in lines) - 1
        sizes = {}
        for key, (typed, integrity) in variants.items():
            cfg = LogzipConfig(level=3, kernel="gzip", format=spec["format"],
                               ise=ISE_FAST)
            cfg.typed_columns = typed
            cfg.integrity = integrity
            blob = compress(lines, cfg)
            assert decompress(blob) == lines, f"{name}: round-trip FAILED"
            sizes[key] = len(blob)
        rows.append({
            "dataset": name,
            "raw_mb": round(raw / 1e6, 3),
            "cr_typed": round(raw / sizes["typed"], 3),
            "cr_v1": round(raw / sizes["v1"], 3),
            "cr_v3": round(raw / sizes["v3"], 3),
            "typed_gain": round(sizes["v1"] / sizes["typed"] - 1, 4),
            "v3_overhead": round(sizes["v3"] / sizes["typed"] - 1, 4),
        })
    return {"n_lines": n_lines, "rows": rows}


def bench_device_pipeline(lines: list[str], fmt: str, n_chunks: int = 20) -> dict:
    """Kernel-path streaming session: bucketed static shapes must make
    chunks 3..n reuse compiled executables (zero re-traces after the
    2-chunk warmup while the template store settles)."""
    import io

    from repro.core.stream import StreamingCompressor
    from repro.kernels import jitcache, ops

    n = len(lines)
    chunk = max(50, n // n_chunks)
    cfg = LogzipConfig(level=3, kernel="gzip", format=fmt,
                       ise=ISEConfig(min_sample=120, max_iters=2, use_kernel=True))
    jitcache.reset_counters()
    buf = io.BytesIO()
    warm_traces: dict | None = None
    t0 = time.perf_counter()
    with StreamingCompressor(buf, cfg, chunk_lines=chunk) as sc:
        k = 0
        for s in range(0, n, chunk):
            sc.feed(lines[s:s + chunk])
            sc.flush_chunk()
            k += 1
            if k == 2:
                warm_traces = dict(jitcache.TRACE_COUNTS)
    wall = time.perf_counter() - t0
    stats = jitcache.bucket_stats()
    recompiles = sum(stats["traces"].values()) - sum((warm_traces or {}).values())
    # record what actually ran, not what was intended: the resolved
    # backend per op (kernel / ref / host after any sticky demotions)
    # and the real interpret flag — check_perf_gate.py annotates
    # interpret-mode numbers so they are never read as accelerator perf
    report = ops.backend_report()
    return {
        "n_lines": n,
        "n_chunks": (n + chunk - 1) // chunk,
        "lines_per_sec": round(n / wall, 1),
        "interpret_mode": bool(ops.INTERPRET),
        "backends": {op: info["backend"] for op, info in report.items()},
        "backend_fallbacks": {op: info["fallbacks"]
                              for op, info in report.items() if info["fallbacks"]},
        "recompiles_after_warmup": int(recompiles),
        "kernel_calls": stats["calls"],
        "kernel_traces": stats["traces"],
        "bucket_shapes": stats["bucket_shapes"],
    }


def bench_compaction(n_lines: int, dataset: str = "HDFS") -> dict:
    """Lifecycle compaction (DESIGN.md §16): merge three dup-heavy
    tenant sessions — same template universe, per-tenant parameter
    streams — into one sealed archive and measure the win against the
    summed sealed inputs plus the recompression throughput. Gated by
    ``check_cr_gate.py``: the compacted archive must be strictly
    smaller than the inputs it replaced, and fsck-clean."""
    import tempfile

    from repro.core import recover
    from repro.core.stream import StreamingCompressor
    from repro.data.loggen import DATASETS
    from repro.lifecycle import compact

    fmt = DATASETS[dataset]["format"]
    per_tenant = max(n_lines // 3, 600)
    cfg = LogzipConfig(level=3, kernel="gzip", format=fmt, ise=ISE_FAST)
    with tempfile.TemporaryDirectory() as d:
        paths = []
        for i in range(3):
            p = os.path.join(d, f"tenant{i}.lzjs")
            with StreamingCompressor(p, cfg,
                                     chunk_lines=max(500, per_tenant // 8)) as sc:
                sc.feed(_dup_heavy(dataset, per_tenant, seed=i))
            paths.append(p)
        out = os.path.join(d, "merged.lzjs")
        t0 = time.perf_counter()
        rep = compact(paths, out)
        wall = time.perf_counter() - t0
        fsck_clean = bool(recover.fsck(out)["clean"])
    return {
        "n_inputs": len(paths),
        "n_lines": rep.n_lines,
        "bytes_in": rep.bytes_in,
        "bytes_out": rep.bytes_out,
        "ratio_vs_inputs": round(rep.bytes_in / rep.bytes_out, 3),
        "templates_in": rep.recluster["templates_in"],
        "templates_out": rep.recluster["templates_out"],
        "wall_s": round(wall, 3),
        "lines_per_sec": round(rep.n_lines / wall, 1),
        "fsck_clean": fsck_clean,
    }


ALL_PARTS = ("nodedup", "dupheavy", "streaming", "device", "query",
             "datasets", "compaction")


def run(n_lines: int = 40000, dataset: str = "HDFS", parts=None) -> dict:
    """Full report, or a subset: ``parts`` names the optional sections
    (``ALL_PARTS``; the "main" scenario always runs — streaming needs its
    CR as the baseline). Skipped sections are ``None`` in the report —
    only write a *full* run to the tracked BENCH artifact."""
    from repro.data.loggen import DATASETS

    sel = set(ALL_PARTS) if parts is None else set(parts)
    unknown = sel - set(ALL_PARTS)
    if unknown:
        raise ValueError(f"unknown part(s) {sorted(unknown)}; "
                         f"available: {list(ALL_PARTS)}")

    fmt = DATASETS[dataset]["format"]
    cfg = LogzipConfig(level=3, kernel="gzip", format=fmt, ise=ISE_FAST)
    cfg_nodedup = LogzipConfig(level=3, kernel="gzip", format=fmt, ise=ISE_FAST, dedup=False)

    lines = list(generate_lines(dataset, n_lines, seed=0))
    results = [bench_one(lines, cfg, f"{dataset}-{n_lines}", scenario="main")]
    if "nodedup" in sel:
        results.append(bench_one(lines, cfg_nodedup, f"{dataset}-{n_lines}-nodedup",
                                 scenario="nodedup"))
    if "dupheavy" in sel:
        results.append(bench_one(_dup_heavy(dataset, n_lines), cfg,
                                 f"{dataset}-{n_lines}-dupheavy",
                                 scenario="dupheavy"))
    fast = results[0]
    streaming = bench_streaming(lines, cfg, fast["compression_ratio"],
                                chunk_lines=max(500, n_lines // 20)) \
        if "streaming" in sel else None
    # interpret-mode kernels are slow on CPU: a small slice exercises the
    # bucketed jit cache without dominating the benchmark wall clock
    device = bench_device_pipeline(lines[: min(n_lines, 4000)], fmt) \
        if "device" in sel else None
    query = bench_query(lines, cfg, chunk_lines=max(500, n_lines // 20)) \
        if "query" in sel else None
    report = {
        "benchmark": "compress_throughput",
        "host": {"platform": platform.platform(), "python": platform.python_version()},
        "config": {"dataset": dataset, "n_lines": n_lines, "level": 3, "kernel": "gzip"},
        "seed_reference": SEED_REFERENCE,
        "speedup_vs_seed": round(fast["lines_per_sec"] / SEED_REFERENCE["lines_per_sec"], 2)
        if n_lines == 40000 and dataset == "HDFS" else None,
        "results": results,
        "streaming": streaming,
        "device_pipeline": device,
        "query": query,
        "datasets": bench_datasets() if "datasets" in sel else None,
        "compaction": bench_compaction(n_lines, dataset)
        if "compaction" in sel else None,
    }
    return report


DEFAULT_REPORT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_compress.json")


def write_report(report: dict, path: str | None = None) -> str:
    """Serialize the report to ``BENCH_compress.json`` (single writer —
    both ``benchmarks.throughput`` and ``benchmarks.run`` go through
    here so the CI artifact never diverges between entry points)."""
    out = os.path.abspath(path or DEFAULT_REPORT_PATH)
    with open(out, "w") as f:
        json.dump(report, f, indent=1)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--lines", type=int, default=40000)
    ap.add_argument("--quick", action="store_true", help="tiny sizes (CI smoke)")
    ap.add_argument("--out", default=DEFAULT_REPORT_PATH)
    args = ap.parse_args()
    report = run(4000 if args.quick else args.lines)
    out = write_report(report, args.out)
    for r in report["results"]:
        print(f"{r['label']:28s} {r['lines_per_sec']:>10.0f} lines/s  "
              f"{r['mb_per_sec']:>7.2f} MB/s  CR {r['compression_ratio']:.2f}")
    if report["speedup_vs_seed"]:
        print(f"speedup vs seed ({SEED_REFERENCE['lines_per_sec']:.0f} lines/s): "
              f"{report['speedup_vs_seed']:.2f}x")
    s = report["streaming"]
    print(f"streaming ({s['n_chunks']} chunks x {s['chunk_lines']} lines): "
          f"CR {s['cr_streaming']:.2f} vs chunked {s['cr_chunked']:.2f} / "
          f"single {s['cr_single']:.2f} -> gap closed {s['cr_gap_closed']:.0%}; "
          f"{s['streaming_lines_per_sec']:.0f} lines/s "
          f"({s['throughput_vs_chunked']:.2f}x chunked)")
    ra = s["random_access"]
    print(f"random access [{ra['start']}:{ra['start']+ra['count']}]: decoded "
          f"{ra['chunks_decoded']}/{ra['chunks_total']} chunks "
          f"(covering {ra['chunks_covering']}) ok={ra['ok']}")
    d = report["device_pipeline"]
    mode = "interpret" if d["interpret_mode"] else "compiled"
    print(f"device pipeline ({mode}, {d['n_chunks']} chunks): "
          f"{d['lines_per_sec']:.0f} lines/s, traces {d['kernel_traces']}, "
          f"recompiles after warmup {d['recompiles_after_warmup']}, "
          f"backends {d['backends']}")
    qy = report["query"]
    for r in qy["queries"]:
        print(f"query[{r['query']:18s}] {r['hits']:5d} hits in {r['wall_s']:.3f}s  "
              f"decoded {r['chunks_opened']}/{r['chunks_total']} chunks "
              f"({r['fraction_chunks_decoded']:.0%})  "
              f"{r['speedup_vs_baseline']:.1f}x vs decompress-then-grep  "
              f"agree={r['hits_agree']}")
    for r in qy["aggregations"]:
        print(f"agg[{r['agg']:20s}] {r['wall_s']:.3f}s  "
              f"opened {r['chunks_opened']} chunks "
              f"(manifest-counted {r['chunks_counted_from_manifest']})  "
              f"{r['speedup_vs_baseline']:.1f}x vs decompress-then-compute  "
              f"rows_mat={r['rows_materialized']}  agree={r['agree']}")
    cf = qy["count_fast_path"]
    print(f"query[count fast path ] {cf['hits']:5d} hits in {cf['wall_s']:.3f}s  "
          f"materialized {cf['rows_materialized']} lines, opened "
          f"{cf['chunks_opened']} chunks "
          f"(manifest-counted {cf['chunks_counted_from_manifest']})")
    print(f"screens: {qy['screen_bytes']}B "
          f"({qy['screen_bytes_fraction']:.2%} of the archive)")
    ds = report["datasets"]
    for r in ds["rows"]:
        print(f"dataset[{r['dataset']:12s}] CR typed {r['cr_typed']:6.2f} vs "
              f"v1 {r['cr_v1']:6.2f}  (+{r['typed_gain']:.1%})  "
              f"v3 {r['cr_v3']:6.2f} (crc cost {r['v3_overhead']:.2%})")
    cp = report["compaction"]
    print(f"compaction: {cp['n_inputs']} sessions ({cp['n_lines']} lines) -> "
          f"{cp['bytes_in']} -> {cp['bytes_out']} B "
          f"({cp['ratio_vs_inputs']:.2f}x vs summed inputs)  "
          f"templates {cp['templates_in']} -> {cp['templates_out']}  "
          f"{cp['lines_per_sec']:.0f} lines/s  fsck_clean={cp['fsck_clean']}")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
