"""Benchmark driver — one section per paper table/figure + ours.

PYTHONPATH=src python -m benchmarks.run [--lines N] [--quick]
Emits CSV-ish sections; EXPERIMENTS.md embeds the output.
"""

from __future__ import annotations

import argparse
import os
import time


def _emit(title: str, rows: list) -> None:
    print(f"\n## {title}")
    if not rows:
        print("(no rows)")
        return
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(f"{r[c]:.3f}" if isinstance(r[c], float) else str(r[c]) for c in cols))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--lines", type=int, default=40000)
    ap.add_argument("--quick", action="store_true", help="tiny sizes (CI)")
    args = ap.parse_args()
    n = 4000 if args.quick else args.lines

    from benchmarks import compression, kernel_bench, throughput

    t0 = time.time()
    report = throughput.run(n)
    # quick runs must not clobber the tracked 40k-line perf-trajectory
    # artifact; they get their own file (CI uploads BENCH_compress*.json)
    throughput.write_report(
        report, path=None if n >= 40000 else
        throughput.DEFAULT_REPORT_PATH.replace(".json", ".quick.json"))
    _emit("Compress throughput (BENCH_compress.json; per-stage breakdown in the file)",
          [{k: r[k] for k in ("label", "lines_per_sec", "mb_per_sec", "compression_ratio")}
           for r in report["results"]])
    s = report["streaming"]
    _emit("Streaming session (shared-store chunked vs independent vs single)",
          [{k: s[k] for k in ("chunk_lines", "cr_single", "cr_chunked", "cr_streaming",
                              "cr_gap_closed", "streaming_lines_per_sec",
                              "throughput_vs_chunked")}])
    _emit("Compressed-domain query (template pushdown vs decompress-then-grep)",
          [{k: r[k] for k in ("query", "hits", "hits_agree", "wall_s",
                              "fraction_chunks_decoded", "speedup_vs_baseline")}
           for r in report["query"]["queries"]])
    _emit("Per-dataset CR — typed column codecs (v2) vs text layout (v1)",
          [{k: r[k] for k in ("dataset", "cr_typed", "cr_v1", "typed_gain")}
           for r in report["datasets"]["rows"]])
    _emit("Table II — compression ratio (synthetic corpora; orderings are the target)",
          compression.table2(n))
    _emit("Fig 6 — compressed MB by logzip level (gzip kernel)",
          compression.fig6_levels(n))
    _emit("Fig 7 — workers / chunking (1-core container: ideal_wall_s = cpu/w)",
          compression.fig7_workers(n))
    _emit("Sec V-D — ISE match rate from ~1% sample",
          compression.match_rate(n if args.quick else max(n, 20000)))
    _emit("Kernel throughput (CPU interpret — relative only)",
          kernel_bench.run(4000 if args.quick else 20000))

    art = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")
    if os.path.isdir(art) and any(f.endswith(".json") for f in os.listdir(art)):
        from benchmarks import roofline

        rows = roofline.load(art)
        print()
        print(roofline.report(rows, "single"))
        print()
        print(roofline.report(rows, "multi"))
    print(f"\ntotal bench time: {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
