"""Benchmark driver — one section per paper table/figure + ours.

PYTHONPATH=src python -m benchmarks.run [--lines N] [--quick] \\
    [--scenarios NAME ...]
Emits CSV-ish sections; EXPERIMENTS.md embeds the output.

``--scenarios`` selects a subset (unknown names exit 2 with the
available list). The tracked BENCH artifact is only written when the
full throughput family runs — a partial report must never clobber the
trajectory the perf gate diffs against.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

# every --scenarios name, in emission order; "soak" is opt-in (it
# streams tens of MB through a live session — minutes, not seconds)
SCENARIOS = ("throughput", "streaming", "query", "datasets", "table2",
             "fig6", "fig7", "match_rate", "kernels", "soak")
DEFAULT_SCENARIOS = tuple(s for s in SCENARIOS if s != "soak")

# scenarios backed by throughput.run() -> the report parts they need
_THROUGHPUT_PARTS = {
    "throughput": {"nodedup", "dupheavy", "device", "compaction"},
    "streaming": {"streaming"},
    "query": {"query"},
    "datasets": {"datasets"},
}


def _emit(title: str, rows: list) -> None:
    print(f"\n## {title}")
    if not rows:
        print("(no rows)")
        return
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(f"{r[c]:.3f}" if isinstance(r[c], float) else str(r[c]) for c in cols))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--lines", type=int, default=40000)
    ap.add_argument("--quick", action="store_true", help="tiny sizes (CI)")
    ap.add_argument("--scenarios", nargs="+", metavar="NAME", default=None,
                    help=f"subset to run; available: {', '.join(SCENARIOS)}")
    args = ap.parse_args()
    n = 4000 if args.quick else args.lines

    sel = list(DEFAULT_SCENARIOS) if args.scenarios is None else \
        [s for tok in args.scenarios for s in tok.split(",") if s]
    unknown = [s for s in sel if s not in SCENARIOS]
    if unknown:
        print(f"unknown scenario(s): {', '.join(unknown)}", file=sys.stderr)
        print(f"available: {', '.join(SCENARIOS)}", file=sys.stderr)
        sys.exit(2)
    sel_set = set(sel)

    from benchmarks import compression, kernel_bench, throughput

    t0 = time.time()
    tp_scenarios = sel_set & set(_THROUGHPUT_PARTS)
    if tp_scenarios:
        full = tp_scenarios == set(_THROUGHPUT_PARTS)
        parts = None if full else \
            set().union(*(_THROUGHPUT_PARTS[s] for s in tp_scenarios))
        report = throughput.run(n, parts=parts)
        if full:
            # quick runs must not clobber the tracked 40k-line perf-
            # trajectory artifact; they get their own file (CI uploads
            # BENCH_compress*.json). Partial reports are never written.
            throughput.write_report(
                report, path=None if n >= 40000 else
                throughput.DEFAULT_REPORT_PATH.replace(".json", ".quick.json"))
    if "throughput" in sel_set:
        _emit("Compress throughput (BENCH_compress.json; per-stage breakdown in the file)",
              [{k: r[k] for k in ("label", "lines_per_sec", "mb_per_sec", "compression_ratio")}
               for r in report["results"]])
    if "streaming" in sel_set:
        s = report["streaming"]
        _emit("Streaming session (shared-store chunked vs independent vs single)",
              [{k: s[k] for k in ("chunk_lines", "cr_single", "cr_chunked", "cr_streaming",
                                  "cr_gap_closed", "streaming_lines_per_sec",
                                  "throughput_vs_chunked")}])
    if "query" in sel_set:
        _emit("Compressed-domain query (template pushdown vs decompress-then-grep)",
              [{k: r[k] for k in ("query", "hits", "hits_agree", "wall_s",
                                  "fraction_chunks_decoded", "speedup_vs_baseline")}
               for r in report["query"]["queries"]])
    if "datasets" in sel_set:
        _emit("Per-dataset CR — typed column codecs (v2) vs text layout (v1)",
              [{k: r[k] for k in ("dataset", "cr_typed", "cr_v1", "typed_gain")}
               for r in report["datasets"]["rows"]])
    if "table2" in sel_set:
        _emit("Table II — compression ratio (synthetic corpora; orderings are the target)",
              compression.table2(n))
    if "fig6" in sel_set:
        _emit("Fig 6 — compressed MB by logzip level (gzip kernel)",
              compression.fig6_levels(n))
    if "fig7" in sel_set:
        _emit("Fig 7 — workers / chunking (1-core container: ideal_wall_s = cpu/w)",
              compression.fig7_workers(n))
    if "match_rate" in sel_set:
        _emit("Sec V-D — ISE match rate from ~1% sample",
              compression.match_rate(n if args.quick else max(n, 20000)))
    if "kernels" in sel_set:
        _emit("Kernel throughput (CPU interpret — relative only)",
              kernel_bench.run(4000 if args.quick else 20000))
    if "soak" in sel_set:
        from benchmarks import soak

        rep = soak.run(int((5 if args.quick else 20) * 1e6))
        r = rep["runs"]["stream"]
        _emit("Soak (stream; full harness: benchmarks/soak.py -> BENCH_soak.json)",
              [{"n_lines": r["n_lines"], "mb_per_sec": r["mb_per_sec"],
                "compression_ratio": r["compression_ratio"],
                "latency_p99_ms": r["latency_ms"]["p99"],
                "rss_peak_mb": r["rss_mb"]["peak"],
                "templates_final": r["growth"]["templates_final"]}])

    art = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")
    if os.path.isdir(art) and any(f.endswith(".json") for f in os.listdir(art)):
        from benchmarks import roofline

        rows = roofline.load(art)
        print()
        print(roofline.report(rows, "single"))
        print()
        print(roofline.report(rows, "multi"))
    print(f"\ntotal bench time: {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
