"""GB-scale soak harness (DESIGN.md §17, ROADMAP item 4).

Streams a parametric workload (`repro.data.loggen.WorkloadSpec`) through
the real write paths — `StreamingCompressor` directly, and/or the ingest
daemon over its socket protocol — while sampling what ≤40k-line
benchmarks cannot observe: RSS over time (bounded memory under template
drift + cardinality ramps), per-batch latency percentiles, and
TemplateStore/ParamDict growth curves. Emits `BENCH_soak.json`;
`scripts/check_soak_gate.py` turns the curves into pass/fail.

    PYTHONPATH=src python -m benchmarks.soak --smoke            # ~100 MB
    PYTHONPATH=src python -m benchmarks.soak --mb 1024          # nightly
    PYTHONPATH=src python -m benchmarks.soak --smoke --daemon   # + socket path

Corpora are deterministic in `(spec, seed)` and generated lazily — a
multi-GB soak never materializes its input.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import platform
import resource
import tempfile
import time

from repro.core.stages import ISEConfig, LogzipConfig
from repro.core.stream import StreamingCompressor
from repro.data.loggen import WorkloadSpec, generate_workload, generate_workload_multitenant

# same fast-ISE settings as benchmarks/throughput.py: soak measures the
# production sampling regime, not exhaustive clustering
ISE_FAST = ISEConfig(sample_rate=0.01, min_sample=400, max_iters=4)

DEFAULT_REPORT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_soak.json")

# the default soak spec leans on every stressor at once: drift rotates
# the statement universe, the ramp streams never-seen parameter values,
# bursts exercise the Markov path, malformed lines hit the verbatim
# channel. Rates are chosen so a 100 MB smoke sees hundreds of drift
# events yet TemplateStore growth stays far below lines (the gate).
SOAK_SPEC = WorkloadSpec(
    n_templates=64, zipf_s=1.1, pool_size=4096, param_reuse=0.6,
    cardinality_ramp=0.25, burstiness=0.6, malformed_rate=0.002,
    drift_rate=0.0005, mutate_fraction=0.5,
)


def _rss_mb() -> float:
    """Current resident set (VmRSS), MB — /proc on linux, peak-RSS
    fallback elsewhere. No new deps (stdlib only)."""
    try:
        with open("/proc/self/status") as f:
            for ln in f:
                if ln.startswith("VmRSS:"):
                    return int(ln.split()[1]) / 1024.0
    except OSError:
        pass
    return _peak_rss_mb()


def _peak_rss_mb() -> float:
    """High-water resident set, MB (`ru_maxrss` is KB on linux)."""
    ru = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    return ru / 1024.0 if platform.system() == "Linux" else ru / (1024.0 ** 2)


def _percentiles(xs: list[float]) -> dict:
    if not xs:
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0, "max": 0.0}
    s = sorted(xs)
    pick = lambda q: s[min(len(s) - 1, int(q * (len(s) - 1)))]  # noqa: E731
    return {"p50": round(pick(0.50), 3), "p95": round(pick(0.95), 3),
            "p99": round(pick(0.99), 3), "max": round(s[-1], 3)}


def _growth_metrics(curve: list[dict], n_lines: int) -> dict:
    """Sublinearity of TemplateStore growth: templates learned in the
    second half of the stream vs the first. A store tracking distinct
    *statements* (drift events) stays well under 1.0 — the first half
    also absorbs the whole initial active set; a store growing with
    *lines* (parse regression: params leaking into templates) pushes
    toward 1.0 and blows the per-1k-lines density cap."""
    if not curve:
        return {}
    t_end = curve[-1]["templates"]
    mid_lines = n_lines / 2
    t_mid = curve[0]["templates"]
    for pt in curve:
        if pt["lines"] <= mid_lines:
            t_mid = pt["templates"]
    out = {
        "templates_final": t_end,
        "params_final": curve[-1]["params"],
        "templates_per_1k_lines": round(t_end / max(1.0, n_lines / 1000.0), 4),
    }
    # store counts advance at chunk cuts; if no chunk landed by the
    # midpoint (tiny daemon soaks) the ratio has no resolution — omit it
    # rather than emit a wild number (the gate skips, density still caps)
    if t_mid > 0:
        out["template_growth_ratio"] = round((t_end - t_mid) / t_mid, 4)
    return out


def _host() -> dict:
    return {"platform": platform.platform(), "python": platform.python_version()}


def _backends() -> dict:
    from repro.kernels import ops

    rep = ops.backend_report()
    return {"interpret_mode": bool(ops.INTERPRET),
            "backends": {op: info["backend"] for op, info in rep.items()}}


def soak_stream(target_bytes: int, *, spec: WorkloadSpec = SOAK_SPEC,
                seed: int = 0, batch_lines: int = 2048,
                chunk_lines: int = 8192, n_samples: int = 64,
                progress=None) -> dict:
    """Stream ~``target_bytes`` of workload through a
    ``StreamingCompressor`` session. Per-batch latency = wall time to
    feed ``batch_lines`` lines (chunk cuts land inside some batches —
    p99 captures those spikes); RSS/store growth sampled ~``n_samples``
    times across the run."""
    fmt_cfg = LogzipConfig(level=3, kernel="gzip", format=spec.format,
                           ise=ISE_FAST)
    gen = iter(generate_workload(spec, None, seed=seed))
    lat_s: list[float] = []
    curve: list[dict] = []
    rss_start = _rss_mb()
    raw = 0
    n_lines = 0
    # sample cadence from the expected line count (bytes / ~90 B-line)
    sample_every = max(1, int(target_bytes / 90 / batch_lines / max(1, n_samples)))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "soak.lzjs")
        t0 = time.perf_counter()
        with StreamingCompressor(path, fmt_cfg, chunk_lines=chunk_lines) as sc:
            batch_no = 0
            while raw < target_bytes:
                batch = []
                for _ in range(batch_lines):
                    ln = next(gen)
                    raw += len(ln) + 1
                    batch.append(ln)
                tb = time.perf_counter()
                sc.feed(batch)
                lat_s.append(time.perf_counter() - tb)
                n_lines += len(batch)
                batch_no += 1
                if batch_no % sample_every == 0:
                    st = sc.stats()
                    curve.append({
                        "lines": n_lines, "templates": st["n_templates"],
                        "params": st["n_params"],
                        "bytes_written": st["bytes_written"],
                        "rss_mb": round(_rss_mb(), 1),
                    })
                    if progress is not None:
                        progress(n_lines, raw, curve[-1])
            # final point AFTER close: the tail buffer flushes there, and
            # store counts only advance at chunk cuts
            summary = sc.close()
            st = sc.stats()
            curve.append({"lines": n_lines, "templates": st["n_templates"],
                          "params": st["n_params"],
                          "bytes_written": st["bytes_written"],
                          "rss_mb": round(_rss_mb(), 1)})
        wall = time.perf_counter() - t0
        compressed = os.path.getsize(path)
    out = {
        "mode": "stream",
        "n_lines": n_lines,
        "raw_bytes": raw,
        "compressed_bytes": compressed,
        "compression_ratio": round(raw / compressed, 3),
        "wall_s": round(wall, 2),
        "lines_per_sec": round(n_lines / wall, 1),
        "mb_per_sec": round(raw / 1e6 / wall, 2),
        "batch_lines": batch_lines,
        "chunk_lines": chunk_lines,
        "n_chunks": summary["n_chunks"],
        "latency_ms": _percentiles([s * 1000 for s in lat_s]),
        "rss_mb": {"start": round(rss_start, 1), "end": round(_rss_mb(), 1),
                   "peak": round(_peak_rss_mb(), 1)},
        "growth": _growth_metrics(curve, n_lines),
        "curve": curve,
    }
    out.update(_backends())
    return out


def soak_daemon(target_bytes: int, *, spec: WorkloadSpec = SOAK_SPEC,
                seed: int = 0, n_tenants: int = 4, batch_lines: int = 512,
                chunk_lines: int = 4096, n_samples: int = 32,
                progress=None) -> dict:
    """Drive ~``target_bytes`` through the ingest daemon over its unix
    socket: ``n_tenants`` interleaved workload streams, one client each.
    Per-batch latency = send ``batch_lines`` lines then block on the
    durability ACK (`wait_ack`) — i.e. the fsync-group-commit round
    trip, the daemon's operational latency number."""
    from repro.ingest import IngestClient
    from repro.ingest.service import IngestDaemon

    tenants = [(f"t{k}", spec) for k in range(n_tenants)]
    # expected lines ~ bytes / 90; interleave is line-count driven
    est_lines = max(batch_lines * n_tenants, int(target_bytes / 90))
    gen = iter(generate_workload_multitenant(tenants, est_lines, seed=seed,
                                             burstiness=0.5))
    lat_s: list[float] = []
    curve: list[dict] = []
    rss_start = _rss_mb()
    raw = 0
    n_lines = 0
    sample_every = max(1, est_lines // batch_lines // max(1, n_samples))
    with tempfile.TemporaryDirectory() as d:
        daemon = IngestDaemon(d, cfg=LogzipConfig(level=3, kernel="gzip",
                                                  format=spec.format,
                                                  ise=ISE_FAST),
                              chunk_lines=chunk_lines,
                              max_tenants=n_tenants + 1).start()
        clients = {tid: IngestClient(daemon.address, tid) for tid, _ in tenants}
        try:
            t0 = time.perf_counter()
            batch_no = 0
            done = False
            while raw < target_bytes and not done:
                last_seq: dict[str, int] = {}
                for _ in range(batch_lines * n_tenants):
                    try:
                        tid, ln = next(gen)
                    except StopIteration:
                        done = True
                        break
                    raw += len(ln) + 1
                    last_seq[tid] = clients[tid].send(ln)
                    n_lines += 1
                tb = time.perf_counter()
                for tid, seq in last_seq.items():
                    clients[tid].wait_ack(seq)
                lat_s.append(time.perf_counter() - tb)
                batch_no += 1
                if batch_no % sample_every == 0:
                    stats = daemon.stats()
                    agg = _agg_tenants(stats)
                    agg.update({"lines": n_lines, "rss_mb": round(_rss_mb(), 1)})
                    curve.append(agg)
                    if progress is not None:
                        progress(n_lines, raw, agg)
            for c in clients.values():
                c.flush()
            stats = daemon.stats()
            agg = _agg_tenants(stats)
            agg.update({"lines": n_lines, "rss_mb": round(_rss_mb(), 1)})
            curve.append(agg)
            wall = time.perf_counter() - t0
        finally:
            for c in clients.values():
                c.close()
            daemon.shutdown()
        compressed = sum(
            os.path.getsize(os.path.join(r, f))
            for r, _dirs, files in os.walk(d) for f in files
            if f.endswith(".lzjs"))
    out = {
        "mode": "daemon",
        "n_tenants": n_tenants,
        "n_lines": n_lines,
        "raw_bytes": raw,
        "compressed_bytes": compressed,
        "compression_ratio": round(raw / max(1, compressed), 3),
        "wall_s": round(wall, 2),
        "lines_per_sec": round(n_lines / wall, 1),
        "mb_per_sec": round(raw / 1e6 / wall, 2),
        "batch_lines": batch_lines,
        "chunk_lines": chunk_lines,
        "latency_ms": _percentiles([s * 1000 for s in lat_s]),
        "rss_mb": {"start": round(rss_start, 1), "end": round(_rss_mb(), 1),
                   "peak": round(_peak_rss_mb(), 1)},
        "growth": _growth_metrics(curve, n_lines),
        "curve": curve,
    }
    out.update(_backends())
    return out


def _agg_tenants(stats: dict) -> dict:
    """Collapse per-tenant daemon stats into one curve point (stores are
    per-tenant: sum sizes — the RSS cap sees their union anyway)."""
    return {
        "templates": sum(s["n_templates"] for s in stats.values()),
        "params": sum(s["n_params"] for s in stats.values()),
        "bytes_written": sum(s["bytes_written"] for s in stats.values()),
        "queue_depth": sum(s["queue_depth"] for s in stats.values()),
    }


def run(target_bytes: int, *, daemon: bool = False,
        daemon_bytes: int | None = None, spec: WorkloadSpec = SOAK_SPEC,
        seed: int = 0, verbose: bool = False) -> dict:
    """Full soak report: always the stream path; optionally the daemon
    path at ``daemon_bytes`` (defaults to a quarter of the stream size —
    socket round trips dominate its wall clock)."""
    prog = None
    if verbose:
        def prog(lines, raw, pt):
            print(f"  {lines:>10,} lines  {raw / 1e6:7.1f} MB  "
                  f"templates {pt.get('templates', '?'):>5}  "
                  f"rss {pt.get('rss_mb', '?')} MB", flush=True)
    report = {
        "benchmark": "soak",
        "host": _host(),
        "spec": dataclasses.asdict(spec),
        "seed": seed,
        "target_mb": round(target_bytes / 1e6, 1),
        "runs": {},
    }
    if verbose:
        print(f"stream soak: {target_bytes / 1e6:.0f} MB target", flush=True)
    report["runs"]["stream"] = soak_stream(target_bytes, spec=spec, seed=seed,
                                           progress=prog)
    if daemon:
        db = daemon_bytes if daemon_bytes is not None else target_bytes // 4
        if verbose:
            print(f"daemon soak: {db / 1e6:.0f} MB target", flush=True)
        report["runs"]["daemon"] = soak_daemon(db, spec=spec, seed=seed,
                                               progress=prog)
    return report


def write_report(report: dict, path: str | None = None) -> str:
    out = os.path.abspath(path or DEFAULT_REPORT_PATH)
    with open(out, "w") as f:
        json.dump(report, f, indent=1)
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="~100 MB stream soak (the required CI job)")
    ap.add_argument("--mb", type=float, default=None,
                    help="stream soak size in MB (nightly: >= 1024)")
    ap.add_argument("--daemon", action="store_true",
                    help="also soak the ingest daemon over its socket")
    ap.add_argument("--daemon-mb", type=float, default=None,
                    help="daemon soak size in MB (default: stream/4)")
    ap.add_argument("--drift", type=float, default=SOAK_SPEC.drift_rate)
    ap.add_argument("--ramp", type=float, default=SOAK_SPEC.cardinality_ramp)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=DEFAULT_REPORT_PATH)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()
    mb = args.mb if args.mb is not None else (100.0 if args.smoke else 100.0)
    spec = dataclasses.replace(SOAK_SPEC, drift_rate=args.drift,
                               cardinality_ramp=args.ramp)
    report = run(int(mb * 1e6), daemon=args.daemon,
                 daemon_bytes=None if args.daemon_mb is None
                 else int(args.daemon_mb * 1e6),
                 spec=spec, seed=args.seed, verbose=not args.quiet)
    out = write_report(report, args.out)
    for mode, r in report["runs"].items():
        g = r["growth"]
        print(f"{mode:7s} {r['n_lines']:>10,} lines  {r['mb_per_sec']:6.2f} MB/s  "
              f"CR {r['compression_ratio']:5.2f}  "
              f"p99 {r['latency_ms']['p99']:7.1f} ms  "
              f"rss peak {r['rss_mb']['peak']:6.1f} MB  "
              f"templates {g['templates_final']} "
              f"(growth ratio {g.get('template_growth_ratio', 'n/a')})")
    print(f"report: {out}")


if __name__ == "__main__":
    main()
